"""Distributed backend — the shard_map executors behind the ``Backend``
protocol (device work in ``repro.solver.distributed`` and
``repro.solver.rowsharded``).

Two mesh decompositions of one plan:

  * ``shard="model"`` (default): the k schedule cores are k devices on
    the mesh's ``model`` axis; every barrier ``all_gather``s the
    superstep's solved values. Simple, but a solve must fit one
    device's plan and barrier traffic is O(k·T) values per device.
  * ``shard="rows"`` (capability ``"shard-rows"``): the plan's rows are
    partitioned into contiguous core blocks (``core.rowshard``), each
    device runs its shard's local scan against a resident x-shard, and
    barriers exchange ONLY the boundary values other shards read —
    static ``ppermute`` rings (or one sparse ``psum``) instead of the
    O(n) all-gather. Also lifts the k <= model-axis restriction (each
    device simulates ``k_local`` lanes).

Both modes execute ``bind(slack=s)`` elastically (capability
``"elastic"``): the fused-run certificate (``core.elastic``) collapses
greedy superstep runs into single exchange rounds — the certificate
guarantees no cross-device read of a value written inside a fused run,
so the fused barrier schedule is exactly as correct as the
per-superstep one. ``describe()`` reports executed vs predicted fusion.

The RHS batch shards over ``data`` in both modes. Jitted solves are
cached per padded batch size, and that cache is SHARED across
``update_values`` clones — the lowered graph is shape-only, so a live
refactorization never recompiles, it only swaps the value operands.
"""
from __future__ import annotations

import dataclasses
import threading

import numpy as np

from repro import obs
from repro.backends.base import (
    Backend,
    BoundSolve,
    expected_entry_count,
    masked_value_gather,
)
from repro.backends.registry import register_backend


class DistributedBoundSolve(BoundSolve):
    backend = "distributed"

    def __init__(self, spec, mesh, args, val_src, diag_src, np_dtype,
                 n_entries, jitted=None, jit_lock=None, exchange_info=None):
        # args = (row_ids, col_idx, vals, diag, accum_mask) device arrays
        self._spec = spec  # solver.distributed.DistPlanSpec (batch unset)
        self._mesh = mesh
        self._args = args
        self._val_src = val_src
        self._diag_src = diag_src
        self._np_dtype = np_dtype
        # static comm telemetry (executed/predicted barrier fusion, comm
        # volume model) merged into describe()["exchange"]
        self._exchange_info = exchange_info
        # padded-batch -> jitted solve; shape-only, shared across value
        # refreshes so serve version swaps reuse every compiled variant.
        # The lock rides along with it: serve worker threads insert while
        # telemetry threads snapshot (describe()).
        self._jitted = {} if jitted is None else jitted
        self._jit_lock = threading.Lock() if jit_lock is None else jit_lock
        self.n = spec.n
        self.n_entries = n_entries

    def solve(self, b):
        import jax
        import jax.numpy as jnp

        from repro.solver.distributed import build_distributed_solver

        b2 = np.asarray(b)
        single = b2.ndim == 1
        b2 = b2[None, :] if single else np.ascontiguousarray(b2.T)
        B = b2.shape[0]
        # the batch shards over 'data': pad it to a multiple
        data_ax = self._mesh.shape["data"]
        Bp = -(-B // data_ax) * data_ax
        b2 = np.concatenate([b2, np.zeros((Bp - B, b2.shape[1]), b2.dtype)])
        b_pad = np.concatenate([b2, np.zeros((Bp, 1), b2.dtype)], axis=1)
        with self._jit_lock:
            fn = self._jitted.get(Bp)
        if fn is None:
            spec = dataclasses.replace(self._spec, batch=Bp)
            fn = jax.jit(build_distributed_solver(spec, self._mesh))
            with self._jit_lock:
                fn = self._jitted.setdefault(Bp, fn)
        with self._mesh:
            x = fn(*self._args, jnp.asarray(b_pad, self._np_dtype))
        # slice/transpose on device — pulling the sharded result through
        # np.asarray and re-uploading it would round-trip host memory per
        # batch; the caller materializes the returned array exactly once
        # (return type consistent with the scan/pallas backends)
        x = x[:, : self.n]
        return x[0] if single else x[:B].T

    def update_values(self, data: np.ndarray) -> "DistributedBoundSolve":
        import jax.numpy as jnp

        with obs.span(
            "backend.update_values", cat="backend", backend=self.backend
        ):
            data = jnp.asarray(
                self._check_data(data).astype(self._np_dtype)
            )
            row_ids, col_idx, vals, diag, accum = self._args
            vals, diag = masked_value_gather(
                data, self._val_src, vals, self._diag_src, diag
            )
        return DistributedBoundSolve(
            self._spec,
            self._mesh,
            (row_ids, col_idx, vals, diag, accum),
            self._val_src,
            self._diag_src,
            self._np_dtype,
            self.n_entries,
            jitted=self._jitted,  # shapes unchanged -> reuse compilations
            jit_lock=self._jit_lock,
            exchange_info=self._exchange_info,
        )

    def describe(self) -> dict:
        with self._jit_lock:  # solve() may be inserting concurrently
            compiled = sorted(self._jitted)
        n_sup = len(self._spec.step_bounds) - 1
        rounds = (
            len(self._spec.exchange_steps) - 1
            if self._spec.exchange_steps is not None
            else n_sup
        )
        # comm-volume model per device per RHS: every barrier gathers
        # each core's xv for the run's steps -> k * T values per solve
        ag_values = int(self._spec.k * self._spec.T)
        exchange = {
            "mode": "all_gather",
            "shard": "model",
            "rounds": rounds,
            "n_supersteps": n_sup,
            "executed_fusion": round(n_sup / max(rounds, 1), 4),
            "comm_values_per_solve": ag_values,
            "comm_bytes_per_solve": ag_values
            * np.dtype(self._np_dtype).itemsize,
        }
        if self._exchange_info:
            exchange.update(self._exchange_info)
        return {
            "backend": self.backend,
            "shard": "model",
            "n": self.n,
            "n_steps": self._spec.T,
            "k": self._spec.k,
            "W": self._spec.W,
            "n_supersteps": n_sup,
            "dtype": np.dtype(self._np_dtype).name,
            "mesh": dict(self._mesh.shape),
            "compiled_batch_sizes": compiled,
            "device_bytes": int(
                sum(a.size * a.dtype.itemsize
                    for a in self._args + (self._val_src, self._diag_src))
            ),
            "exchange": exchange,
        }


class RowShardedBoundSolve(BoundSolve):
    """The ``shard="rows"`` bound: per-device local plans with resident
    x-shards and halo exchange (``core.rowshard`` partition,
    ``solver.rowsharded`` executor). ``update_values`` gathers new entry
    data through the stacked GLOBAL-entry source maps — each shard's
    local plan keeps the caller's entry ids, so a refactorization is one
    device gather, no repartition."""

    backend = "distributed"

    def __init__(self, rsp, mesh, mode, plan_args, halo_args, val_src,
                 diag_src, np_dtype, n_entries, exchange_info=None,
                 jitted=None, jit_lock=None):
        self._rsp = rsp  # core.rowshard.RowShardPlan (host tensors)
        self._mesh = mesh
        self._mode = mode  # "ring" | "psum"
        self._plan_args = plan_args  # stacked [n_shards, T, k_local, ...]
        self._halo_args = halo_args  # flat int32 exchange tables
        self._val_src = val_src  # stacked GLOBAL entry ids
        self._diag_src = diag_src
        self._np_dtype = np_dtype
        self._exchange_info = exchange_info
        # padded-batch -> jitted solve (0 = single RHS); shared across
        # update_values clones like the model-axis bound. The timed path
        # keeps its per-round fns under negative-keyed entries.
        self._jitted = {} if jitted is None else jitted
        self._jit_lock = threading.Lock() if jit_lock is None else jit_lock
        self.n = rsp.n
        self.n_entries = n_entries
        self._comm = rsp.comm_stats(np.dtype(np_dtype).itemsize)

    # ---------------------------------------------------------- helpers
    def _spec(self, batch: int):
        from repro.solver.rowsharded import rowshard_spec

        return rowshard_spec(self._rsp, mode=self._mode, batch=batch)

    def _scatter_b(self, b2, mp):
        """Host-scatter the rhs into per-shard local slots. b2 f[n, mp]
        or f[n] -> f[n_shards, slots(, mp)] (halo/scratch slots zero)."""
        rsp = self._rsp
        slots = rsp.slots
        if b2.ndim == 1:
            bl = np.zeros(rsp.n_shards * slots, self._np_dtype)
            bl[rsp.b_scatter] = b2
            return bl.reshape(rsp.n_shards, slots)
        bl = np.zeros((rsp.n_shards * slots, mp), self._np_dtype)
        bl[rsp.b_scatter] = b2
        return bl.reshape(rsp.n_shards, slots, mp)

    def _gather_x(self, out, m=None):
        """Stacked owned regions -> global row order (device-side)."""
        import jax.numpy as jnp

        rsp = self._rsp
        gather = jnp.asarray(rsp.x_gather, jnp.int32)
        if m is None:
            return out.reshape(rsp.n_shards * rsp.n_loc)[gather]
        return out.reshape(rsp.n_shards * rsp.n_loc, -1)[gather]

    def _count_comm(self, n_rhs: int):
        per = (
            self._comm["halo_values_psum"]
            if self._mode == "psum"
            else self._comm["halo_values_per_solve"]
        )
        obs.counter_add("rowshard.halo_values", per * n_rhs)
        obs.counter_add(
            "rowshard.halo_bytes",
            per * n_rhs * np.dtype(self._np_dtype).itemsize,
        )

    # ------------------------------------------------------------ solve
    def solve(self, b):
        import jax
        import jax.numpy as jnp

        from repro.solver.rowsharded import build_rowsharded_solver

        b2 = np.asarray(b).astype(self._np_dtype)
        single = b2.ndim == 1
        if single:
            key, mp = 0, None
        else:
            m = b2.shape[1]
            data_ax = self._mesh.shape["data"]
            mp = -(-m // data_ax) * data_ax
            if mp > m:
                b2 = np.concatenate(
                    [b2, np.zeros((b2.shape[0], mp - m), b2.dtype)], axis=1
                )
            key = mp
        with self._jit_lock:
            fn = self._jitted.get(key)
        if fn is None:
            spec = self._spec(0 if single else mp)
            fn = jax.jit(build_rowsharded_solver(spec, self._mesh))
            with self._jit_lock:
                fn = self._jitted.setdefault(key, fn)
        b_loc = jnp.asarray(self._scatter_b(b2, mp))
        self._count_comm(1 if single else mp)
        with obs.span(
            "rowshard.solve",
            cat="backend",
            n=self.n,
            n_shards=self._rsp.n_shards,
            mode=self._mode,
            halo_bytes=self._comm["halo_bytes_per_solve"],
        ):
            with self._mesh:
                out = fn(*self._plan_args, *self._halo_args, b_loc)
            x = self._gather_x(out, m=None if single else mp)
        return x if single else x[:, : m]

    def solve_timed(self, b):
        """Per-exchange-round device timing: each round (local scan +
        its halo exchange) runs as one shard-mapped call on a carried
        global x, host-timed around ``block_until_ready`` — the runtime
        side of the halo-vs-all_gather comm claim. Numerically identical
        to ``solve`` (same step bodies, same exchange ops; the per-round
        accumulator re-zeroes are exact because virtual-row chains never
        span a superstep barrier)."""
        import time as _time

        import jax
        import jax.numpy as jnp

        from repro.solver.rowsharded import (
            build_rowsharded_round,
            halo_args_for_round,
        )

        rsp = self._rsp
        b2 = np.asarray(b).astype(self._np_dtype)
        single = b2.ndim == 1
        if single:
            mp = None
            batch = 0
        else:
            m = b2.shape[1]
            data_ax = self._mesh.shape["data"]
            mp = -(-m // data_ax) * data_ax
            if mp > m:
                b2 = np.concatenate(
                    [b2, np.zeros((b2.shape[0], mp - m), b2.dtype)], axis=1
                )
            batch = mp
        spec = self._spec(batch)
        b_loc = jnp.asarray(self._scatter_b(b2, mp))
        shape = (
            (rsp.n_shards, spec.slots)
            if single
            else (rsp.n_shards, spec.slots, mp)
        )
        x_glob = jnp.zeros(shape, self._np_dtype)
        self._count_comm(1 if single else mp)
        sb, eb = spec.step_bounds, spec.exchange_bounds
        steps = []
        itemsize = np.dtype(self._np_dtype).itemsize
        n_rhs = 1 if single else mp
        with self._mesh:
            for r in range(spec.n_rounds):
                key = (-1, r, batch)
                with self._jit_lock:
                    fn = self._jitted.get(key)
                if fn is None:
                    fn = jax.jit(
                        build_rowsharded_round(spec, self._mesh, r)
                    )
                    with self._jit_lock:
                        fn = self._jitted.setdefault(key, fn)
                halo = (
                    halo_args_for_round(rsp, r, self._mode)
                    if r < spec.n_rounds - 1
                    else ()
                )
                hv = (
                    rsp.rounds[r].ring_values
                    if self._mode == "ring"
                    else rsp.rounds[r].buf_size
                ) if r < spec.n_rounds - 1 else 0
                with obs.span(
                    "executor.exchange_round",
                    cat="executor",
                    round=r,
                    supersteps=eb[r + 1] - eb[r],
                    halo_bytes=hv * itemsize * n_rhs,
                ):
                    t0 = _time.perf_counter_ns()
                    x_glob = fn(
                        *self._plan_args, *halo, b_loc, x_glob
                    )
                    x_glob.block_until_ready()
                    dur = _time.perf_counter_ns() - t0
                steps.append(
                    {
                        "round": r,
                        "n_steps": sb[eb[r + 1]] - sb[eb[r]],
                        "halo_values": hv * n_rhs,
                        "halo_bytes": hv * itemsize * n_rhs,
                        "us": round(dur / 1e3, 2),
                    }
                )
            x = self._gather_x(
                x_glob[:, : rsp.n_loc], m=None if single else mp
            )
        return (x if single else x[:, : m]), steps

    def update_values(self, data: np.ndarray) -> "RowShardedBoundSolve":
        import jax.numpy as jnp

        with obs.span(
            "backend.update_values", cat="backend", backend=self.backend
        ):
            data = jnp.asarray(
                self._check_data(data).astype(self._np_dtype)
            )
            rows, cols, vals, diag, accum = self._plan_args
            vals, diag = masked_value_gather(
                data, self._val_src, vals, self._diag_src, diag
            )
        return RowShardedBoundSolve(
            self._rsp,
            self._mesh,
            self._mode,
            (rows, cols, vals, diag, accum),
            self._halo_args,
            self._val_src,
            self._diag_src,
            self._np_dtype,
            self.n_entries,
            exchange_info=self._exchange_info,
            jitted=self._jitted,  # shapes unchanged -> reuse compilations
            jit_lock=self._jit_lock,
        )

    def describe(self) -> dict:
        with self._jit_lock:
            compiled = sorted(
                k for k in self._jitted if not isinstance(k, tuple)
            )
        rsp = self._rsp
        n_sup = len(rsp.step_bounds) - 1
        exchange = {
            "mode": self._mode,
            "shard": "rows",
            "rounds": rsp.n_rounds,
            "n_supersteps": n_sup,
            "executed_fusion": round(n_sup / max(rsp.n_rounds, 1), 4),
            "comm_values_per_solve": (
                self._comm["halo_values_psum"]
                if self._mode == "psum"
                else self._comm["halo_values_per_solve"]
            ),
            "comm_bytes_per_solve": (
                self._comm["halo_values_psum"]
                if self._mode == "psum"
                else self._comm["halo_values_per_solve"]
            ) * np.dtype(self._np_dtype).itemsize,
            **{
                k: self._comm[k]
                for k in (
                    "halo_pairs",
                    "halo_values_per_solve",
                    "halo_bytes_per_solve",
                    "halo_values_max_round",
                    "allgather_values",
                    "allgather_bytes",
                    "halo_ratio",
                    "active_exchanges",
                )
            },
        }
        if self._exchange_info:
            exchange.update(self._exchange_info)
        return {
            "backend": self.backend,
            "shard": "rows",
            "n": self.n,
            "n_steps": rsp.T,
            "k": rsp.n_shards * rsp.k_local,
            "k_local": rsp.k_local,
            "W": rsp.W,
            "n_shards": rsp.n_shards,
            "n_loc": rsp.n_loc,
            "n_halo": rsp.n_halo,
            "n_supersteps": n_sup,
            "dtype": np.dtype(self._np_dtype).name,
            "mesh": dict(self._mesh.shape),
            "compiled_batch_sizes": compiled,
            "device_bytes": int(
                sum(
                    a.size * a.dtype.itemsize
                    for a in self._plan_args
                    + self._halo_args
                    + (self._val_src, self._diag_src)
                )
            ),
            "exchange": exchange,
        }


def _pad_cores(plan, model_ax: int):
    """Pad the plan's core axis UP to the mesh's ``model`` axis size so
    narrower schedules (e.g. serial's k=1 chains) shard cleanly — the
    executor assigns exactly one schedule core per model-axis device, so
    k must end up equal to it. A plan with MORE cores than devices
    cannot be executed (each device's scan walks one chain) and is
    rejected with a clear error instead of failing at trace time.
    Padding lanes follow the plan's own protocol — row id n (scratch),
    self-gathers, val 0 / diag 1, source maps -1 — so they compute
    harmless writes to the scratch slot."""
    k, kp = plan.k, model_ax
    if k > model_ax:
        raise ValueError(
            f"distributed backend: plan has k={k} schedule cores but the "
            f"mesh 'model' axis has only {model_ax} devices — schedule "
            f"with k <= mesh.shape['model'] (one core per device)"
        )
    if kp == k:
        return plan
    T, pad = plan.n_steps, kp - k

    def padk(a, fill):
        block = np.full((T, pad, *a.shape[2:]), fill, dtype=a.dtype)
        return np.concatenate([a, block], axis=1)

    return dataclasses.replace(
        plan,
        k=kp,
        row_ids=padk(plan.row_ids, plan.n),
        col_idx=padk(plan.col_idx, plan.n),
        vals=padk(plan.vals, 0),
        diag=padk(plan.diag, 1),
        accum=padk(plan.accum, False),
        val_src=None if plan.val_src is None else padk(plan.val_src, -1),
        diag_src=None if plan.diag_src is None else padk(plan.diag_src, -1),
    )


@register_backend
class DistributedBackend(Backend):
    """BSP on a device mesh: ``shard="model"`` — one all-gather barrier
    per exchange round; ``shard="rows"`` — row partition with halo
    exchange. ``bind(slack=s)`` fuses certified superstep runs into
    single exchange rounds in either mode."""

    name = "distributed"

    def requires(self):
        return ("mesh",)

    def capabilities(self):
        return ("elastic", "shard-rows")

    def bind(self, exec_plan, *, dtype=np.float32, steps_per_tile=8,
             interpret=None, mesh=None, slack=0, shard="model"):
        with obs.span(
            "backend.bind",
            cat="backend",
            backend=self.name,
            n=exec_plan.n,
            slack=slack,
            shard=shard,
        ):
            return self._bind(
                exec_plan, dtype=dtype, mesh=mesh, slack=slack, shard=shard
            )

    @staticmethod
    def _fused(exec_plan, slack):
        """The elastic certificate for ``slack`` (reuses the plan's
        attached transform when it matches)."""
        from repro.core.elastic import elastic_transform

        ep = exec_plan.elastic
        if ep is None or ep.slack != slack:
            ep = elastic_transform(exec_plan, slack)
        return ep

    def _bind(self, exec_plan, *, dtype, mesh, slack, shard):
        if mesh is None:
            raise ValueError("backend='distributed' requires a mesh")
        if shard not in ("model", "rows"):
            raise ValueError(
                f"backend='distributed': unknown shard mode {shard!r} "
                "(expected 'model' or 'rows')"
            )
        np_dtype = np.dtype(dtype)
        fused = self._fused(exec_plan, slack) if slack > 0 else None
        if shard == "rows":
            return self._bind_rows(exec_plan, np_dtype, mesh, fused, slack)
        return self._bind_model(exec_plan, np_dtype, mesh, fused, slack)

    def _bind_model(self, exec_plan, np_dtype, mesh, fused, slack):
        import jax.numpy as jnp

        from repro.solver.distributed import dist_plan_spec

        exec_plan = _pad_cores(exec_plan, mesh.shape["model"])
        exchange_steps = None
        exchange_info = None
        if fused is not None:
            # execute the certificate: one all-gather per fused run
            sb = np.asarray(exec_plan.step_bounds)
            exchange_steps = tuple(
                int(t) for t in sb[np.asarray(fused.fused_bounds)]
            )
            cert = fused.stats()
            exchange_info = {
                "slack": slack,
                "predicted_rounds": fused.n_fused_supersteps,
                "predicted_fusion": cert["barrier_fusion"],
            }
        spec = dist_plan_spec(
            exec_plan, batch=0, dtype=np_dtype, exchange_steps=exchange_steps
        )
        args = (
            jnp.asarray(exec_plan.row_ids, jnp.int32),
            jnp.asarray(exec_plan.col_idx, jnp.int32),
            jnp.asarray(exec_plan.vals, np_dtype),
            jnp.asarray(exec_plan.diag, np_dtype),
            jnp.asarray(exec_plan.accum.astype(np_dtype)),
        )
        assert exec_plan.val_src is not None and exec_plan.diag_src is not None
        return DistributedBoundSolve(
            spec,
            mesh,
            args,
            jnp.asarray(exec_plan.val_src, jnp.int32),
            jnp.asarray(exec_plan.diag_src, jnp.int32),
            np_dtype,
            expected_entry_count(exec_plan),
            exchange_info=exchange_info,
        )

    def _bind_rows(self, exec_plan, np_dtype, mesh, fused, slack):
        import jax.numpy as jnp

        from repro.core.rowshard import partition_plan
        from repro.solver.rowsharded import (
            rowshard_halo_args,
            rowshard_plan_args,
        )

        assert exec_plan.val_src is not None and exec_plan.diag_src is not None
        n_shards = mesh.shape["model"]
        exchange_info = None
        bounds = None
        if fused is not None:
            bounds = fused.fused_bounds
            cert = fused.stats()
            exchange_info = {
                "slack": slack,
                "predicted_rounds": fused.n_fused_supersteps,
                "predicted_fusion": cert["barrier_fusion"],
            }
        rsp = partition_plan(exec_plan, n_shards, exchange_bounds=bounds)
        plan_args = rowshard_plan_args(rsp, dtype=jnp.dtype(np_dtype.name))
        mode = "ring"  # bitwise-safe default; psum is bench/opt-in
        halo_args = rowshard_halo_args(rsp, mode)
        # GLOBAL entry ids per shard: one gather refreshes all shards
        val_src = jnp.asarray(
            np.stack([s.val_src for s in rsp.shards]), jnp.int32
        )
        diag_src = jnp.asarray(
            np.stack([s.diag_src for s in rsp.shards]), jnp.int32
        )
        return RowShardedBoundSolve(
            rsp,
            mesh,
            mode,
            plan_args,
            halo_args,
            val_src,
            diag_src,
            np_dtype,
            expected_entry_count(exec_plan),
            exchange_info=exchange_info,
        )
