"""Backend registry — the single source of truth for execution backends.

Every consumer that used to hard-code backend strings iterates this
registry instead: ``TriangularSolver._bind``, the conformance grid, the
autotuner's ``tune=True`` trial runner, and serve telemetry. Registering
a backend makes it reachable from all of them at once:

    from repro.backends import Backend, register_backend

    @register_backend
    class MeshShardedServe(Backend):
        name = "mesh-serve"
        def bind(self, exec_plan, **params): ...
"""
from __future__ import annotations

import threading
from typing import Dict, Tuple

from repro.backends.base import Backend, BoundSolve

_LOCK = threading.Lock()
_REGISTRY: Dict[str, Backend] = {}


def register_backend(backend_cls):
    """Class decorator (or plain call) registering a ``Backend``. The
    class is instantiated once; its ``name`` attribute is the registry
    key. Duplicate names are rejected — shadowing an existing backend
    silently would change what every consumer binds."""
    instance = backend_cls() if isinstance(backend_cls, type) else backend_cls
    name = getattr(instance, "name", None)
    if not name or not isinstance(name, str):
        raise ValueError("backend must define a non-empty string `name`")
    with _LOCK:
        if name in _REGISTRY:
            raise ValueError(f"backend {name!r} already registered")
        _REGISTRY[name] = instance
    return backend_cls


def unregister_backend(name: str) -> None:
    """Remove a registry entry (tests cleaning up custom backends)."""
    with _LOCK:
        _REGISTRY.pop(name, None)


def get_backend(name: str) -> Backend:
    with _LOCK:
        backend = _REGISTRY.get(name)
    if backend is None:
        raise ValueError(
            f"unknown backend {name!r}; expected one of {available_backends()}"
        )
    return backend


def available_backends() -> Tuple[str, ...]:
    """Registered backend names, in registration order (the built-ins
    register as scan, pallas, distributed on package import)."""
    with _LOCK:
        return tuple(_REGISTRY)


def backends_with(capability: str) -> Tuple[str, ...]:
    """Registered backend names advertising ``capability`` (see
    ``Backend.capabilities``) — e.g. ``backends_with("grouped")`` names
    the backends the serve layer can width-class-batch across patterns."""
    with _LOCK:
        entries = list(_REGISTRY.items())
    return tuple(
        name for name, be in entries if capability in be.capabilities()
    )


def bind(name: str, exec_plan, **params) -> BoundSolve:
    """Convenience: ``get_backend(name).bind(exec_plan, **params)``."""
    return get_backend(name).bind(exec_plan, **params)
