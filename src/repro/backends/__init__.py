"""``repro.backends`` — execution backends behind one protocol.

Every way this repo can execute a compiled ``ExecPlan`` — the single-chip
`lax.scan` executor, the Pallas TPU kernel, the shard_map distributed
solver — is a ``Backend`` registered here and bound through one call:

    from repro.backends import get_backend

    bound = get_backend("scan").bind(exec_plan, dtype=np.float32)
    x = bound.solve(b)                       # f[n] or f[n, m]
    bound2 = bound.update_values(new_data)   # device-side refresh, O(nnz)
    print(bound.describe())                  # telemetry for bench/serve

``TriangularSolver``, the conformance grid, the autotuner's measured
trials and serve telemetry all iterate this registry — adding a backend
(e.g. a mesh-sharded serve binding) is one ``register_backend`` call.

Module map:

  * ``base``        — ``Backend`` / ``BoundSolve`` protocol +
                      ``masked_value_gather`` (the shared device refresh)
  * ``registry``    — ``register_backend`` / ``get_backend`` /
                      ``available_backends``
  * ``scan``        — single-chip `lax.scan` executor binding
  * ``pallas``      — Pallas TPU kernel binding (interpret mode on CPU)
  * ``distributed`` — shard_map mesh binding (requires ``mesh=``)
"""
from repro.backends.base import Backend, BoundSolve, masked_value_gather
from repro.backends.registry import (
    available_backends,
    backends_with,
    bind,
    get_backend,
    register_backend,
    unregister_backend,
)

# importing the built-in implementations registers them (in this order)
from repro.backends import scan as _scan  # noqa: E402,F401
from repro.backends import pallas as _pallas  # noqa: E402,F401
from repro.backends import distributed as _distributed  # noqa: E402,F401

__all__ = [
    "Backend",
    "BoundSolve",
    "masked_value_gather",
    "available_backends",
    "backends_with",
    "bind",
    "get_backend",
    "register_backend",
    "unregister_backend",
]
