"""The execution-backend protocol: ``Backend.bind(plan) -> BoundSolve``.

One contract replaces the three divergent device-tensor conversions that
used to live in ``solver/executor.py`` (scan), ``kernels/ops.py``
(pallas tile setup) and ``solver/distributed.py`` (mesh sharding), and
the ``if/elif`` binding block in ``pipeline/solver.py``:

  * ``Backend`` — a named, stateless factory. ``bind(exec_plan,
    **params)`` transfers the plan tensors to the device(s) once and
    returns a ``BoundSolve``. Binding parameters every backend receives
    (and ignores if irrelevant): ``dtype``, ``steps_per_tile``,
    ``interpret``, ``mesh``.
  * ``BoundSolve`` — an immutable bound solver:
      - ``solve(b)`` for ``b`` f[n] or f[n, m] (multi-RHS);
      - ``update_values(data) -> BoundSolve`` refreshes the numeric
        values *on device* — a gather of the new entry data through the
        plan's ``val_src``/``diag_src`` maps — and returns a NEW bound
        solve sharing the (read-only) index tensors. The old bound keeps
        serving in-flight work untouched (the live-refactorization
        primitive ``repro.serve`` version-swaps on), and nothing
        round-trips the full [T, k, W] plan tensors through host memory;
      - ``describe()`` — a JSON-ready dict for bench/serve telemetry.

The value contract ``update_values`` must honor (conformance-tested on
every registered backend): a solve after ``update_values(data)`` is
bitwise-identical to a solve on a fresh ``bind`` of a plan compiled from
the same pattern with ``data``.

Register implementations with ``repro.backends.register_backend``; every
consumer (``TriangularSolver``, the conformance grid, the autotuner's
trial runner, serve telemetry) iterates the registry, so a new backend is
one registry entry — never another ``elif``.
"""
from __future__ import annotations

import abc
from typing import Tuple

import numpy as np

from repro import obs


def _masked_value_gather_jit():
    """Build (once) the jitted gather+mask kernel — jit fuses the two
    gathers and selects into one pass per tensor instead of four eager
    dispatches, and the compiled variant is cached per plan shape."""
    import jax
    import jax.numpy as jnp

    @jax.jit
    def gather(data, val_src, vals_old, diag_src, diag_old):
        # negative indices are masked by the where(); jax clamps them in
        # the gather, so no out-of-bounds access happens either way
        vals = jnp.where(val_src >= 0, data[val_src], vals_old)
        diag = jnp.where(diag_src >= 0, data[diag_src], diag_old)
        return vals, diag

    return gather


_GATHER = None


def masked_value_gather(data, val_src, vals_old, diag_src, diag_old):
    """The shared device-side numeric refresh: gather ``data`` (the new
    matrix's entry values, already cast to the plan dtype) through the
    source maps, keeping the old value wherever the map says padding
    (``src < 0``). Returns ``(vals, diag)`` as new device arrays.

    Bitwise-identical to the host path (``ExecPlan.numeric_update`` +
    retransfer): the f64 -> plan-dtype cast happens per element on the
    host, and the gather itself moves bits unchanged; padding slots keep
    their original contents exactly as the in-place host mutate does.
    """
    global _GATHER
    if _GATHER is None:
        _GATHER = _masked_value_gather_jit()
    return _GATHER(data, val_src, vals_old, diag_src, diag_old)


def expected_entry_count(exec_plan) -> int:
    """Length the ``update_values`` data vector must have: the planned
    pattern's entry count, recovered from the source maps (every stored
    entry of a full-diagonal matrix is referenced by exactly one of
    them, so the max index + 1 is the nnz)."""
    hi = -1
    if exec_plan.val_src is not None and exec_plan.val_src.size:
        hi = max(hi, int(exec_plan.val_src.max()))
    if exec_plan.diag_src is not None and exec_plan.diag_src.size:
        hi = max(hi, int(exec_plan.diag_src.max()))
    return hi + 1


class BoundSolve(abc.ABC):
    """A plan bound to one execution backend. Immutable: value refreshes
    return a new instance (see module docstring)."""

    backend: str  # registry name of the backend that produced this
    n: int  # problem size (scratch row excluded)
    n_entries: int  # entry count update_values data must match

    # width-class grouping: True when this bound can solve one rhs per
    # plan in a single fused dispatch. Requires the compiled solve graph
    # to depend only on the plan tensor SHAPES — the scan backend
    # qualifies (step_bounds never enter its trace); backends whose
    # graph bakes in plan contents (distributed superstep bounds, pallas
    # grids) must leave this False. Advertising it is a THREE-method
    # contract: ``solve_grouped`` (stack-per-call; the replay/reference
    # path) plus ``stack_bank``/``solve_bank`` (the serving fast path —
    # ``repro.pipeline.GroupBank`` dispatches through them, so a backend
    # that only implements ``solve_grouped`` must not set this flag).
    supports_grouped: bool = False

    @classmethod
    def solve_grouped(cls, bounds, b_cols):
        """Solve lane j of ``b_cols`` f[g, n] (plan row order) against
        ``bounds[j]`` — one dispatch for the whole group. All bounds must
        share one width class (identical plan tensor shapes, same dtype).
        Returns x f[g, n]. Only meaningful when ``supports_grouped``."""
        raise NotImplementedError(
            f"backend {cls.backend!r} does not support width-class "
            "grouped solves"
        )

    @classmethod
    def stack_bank(cls, bounds, perms, invs):
        """Stack one width class's bounds into an opaque device bank
        (lane axis first) with per-lane row permutations ``perms``/
        ``invs`` — restacked only when membership changes. Returned
        value is backend-defined; it is only ever passed back to
        ``solve_bank``."""
        raise NotImplementedError(
            f"backend {cls.backend!r} does not support width-class "
            "grouped solves (no bank support)"
        )

    @classmethod
    def solve_bank(cls, bank, lane_idx, B):
        """Solve column j of ``B`` f[n, m] (caller row order) against
        bank lane ``lane_idx[j]``; returns x f[n, m] (caller order),
        bitwise-identical to ``solve_grouped`` on the same lanes."""
        raise NotImplementedError(
            f"backend {cls.backend!r} does not support width-class "
            "grouped solves (no bank support)"
        )

    # resident RHS slots — the continuous-batching serve contract
    # (capability ``"slots"``). Four classmethods on top of the bank
    # contract: a device-resident rhs bank f[n, S] that admission writes
    # into slot-by-slot (``insert_lane``), the always-running dispatch
    # loop solves at the fixed width S (``solve_resident`` — bitwise-
    # identical to ``solve_bank`` on the same lanes), and completion
    # reads out of (``extract_lane``). All three device ops move bits
    # unchanged and must not perturb neighbor slots.
    @classmethod
    def blank_rhs(cls, n, slots, dtype):
        """A zeroed device-resident rhs bank f[n, slots]."""
        raise NotImplementedError(
            f"backend {cls.backend!r} does not support resident RHS "
            "slots (no 'slots' capability)"
        )

    @classmethod
    def insert_lane(cls, B_res, lane, b):
        """A NEW resident bank with column ``lane`` replaced by ``b``
        f[n]; every other column's bits are untouched and the input
        bank is not mutated (in-flight passes keep their snapshot)."""
        raise NotImplementedError(
            f"backend {cls.backend!r} does not support resident RHS "
            "slots (no 'slots' capability)"
        )

    @classmethod
    def extract_lane(cls, X, lane):
        """Column ``lane`` of a pass result ``X`` f[n, S] as f[n],
        bits unchanged."""
        raise NotImplementedError(
            f"backend {cls.backend!r} does not support resident RHS "
            "slots (no 'slots' capability)"
        )

    @classmethod
    def solve_resident(cls, bank, lane_idx, B_res):
        """One continuous-mode dispatch pass: solves the first
        ``len(lane_idx)`` columns of the resident bank (the engine's
        pow2 occupied-lane prefix — lightly-loaded banks never pay the
        full-S solve), bitwise-identical to ``solve_bank`` on that
        prefix; ``B_res`` is already on device, so nothing re-uploads."""
        raise NotImplementedError(
            f"backend {cls.backend!r} does not support resident RHS "
            "slots (no 'slots' capability)"
        )

    def _check_data(self, data: np.ndarray) -> np.ndarray:
        """Reject mis-sized update data. The device gather clamps
        out-of-range indices (same hazard solve() guards against for b),
        so without this check a wrong-pattern data vector would silently
        produce garbage values instead of raising."""
        data = np.asarray(data)
        if data.ndim != 1 or data.shape[0] != self.n_entries:
            raise ValueError(
                f"update_values expects the planned pattern's entry data "
                f"f[{self.n_entries}]; got shape {data.shape}"
            )
        return data

    @abc.abstractmethod
    def solve(self, b):
        """Solve for ``b`` f[n] or f[n, m]; returns x shaped like b."""

    def solve_timed(self, b):
        """``solve`` plus per-step device timings: returns ``(x, steps)``
        where ``steps`` is a list of JSON-ready dicts, finest granularity
        the backend can observe. This base fallback times the whole
        blocked solve as ONE step (backends without segmented dispatch —
        pallas tiles, shard_map supersteps — still report a synchronized
        wall-clock); the scan backend overrides it with per-superstep
        (bulk) / per-macro-step (elastic) segments."""
        import time as _time

        with obs.span("executor.solve_timed", cat="executor", n=self.n):
            t0 = _time.perf_counter_ns()
            x = self.solve(b)
            try:
                x.block_until_ready()
            except AttributeError:  # plain ndarray result
                pass
            dur = _time.perf_counter_ns() - t0
        return x, [{"step": 0, "n_steps": None, "us": round(dur / 1e3, 2)}]

    @abc.abstractmethod
    def update_values(self, data: np.ndarray) -> "BoundSolve":
        """Device-side numeric refresh from ``data`` (the ``.data`` of a
        matrix with the planned pattern, in plan entry order). Returns a
        NEW BoundSolve sharing index tensors; self is untouched."""

    @abc.abstractmethod
    def describe(self) -> dict:
        """JSON-ready binding telemetry (backend, shapes, device bytes)."""


class Backend(abc.ABC):
    """A named execution backend — a ``BoundSolve`` factory."""

    name: str

    @abc.abstractmethod
    def bind(
        self,
        exec_plan,
        *,
        dtype=np.float32,
        steps_per_tile: int = 8,
        interpret=None,
        mesh=None,
        slack: int = 0,
        shard: str = "model",
    ) -> BoundSolve:
        """Transfer ``exec_plan``'s tensors and return a ``BoundSolve``.
        Irrelevant parameters are accepted and ignored so callers can
        pass one uniform binding-parameter set to every backend.

        ``slack > 0`` requests ``mode="elastic"`` (bounded-slack
        macro-step execution, see ``core.elastic``); backends that do
        not advertise the ``"elastic"`` capability must raise a clear
        error rather than silently fall back to bulk-synchronous.

        ``shard`` selects the mesh decomposition for multi-device
        backends: ``"model"`` (default — k schedule cores over the
        model axis) or ``"rows"`` (row partition + halo exchange,
        capability ``"shard-rows"``). Backends that do not advertise
        the requested mode must raise, not silently rebind."""

    def requires(self) -> Tuple[str, ...]:
        """Names of binding params this backend cannot run without
        (e.g. ``("mesh",)`` for the distributed backend)."""
        return ()

    def capabilities(self) -> Tuple[str, ...]:
        """Optional feature names this backend's bounds implement beyond
        the core contract. Known capabilities: ``"grouped"`` — the bound
        solves one rhs per plan in a single width-class dispatch
        (``BoundSolve.solve_grouped``; the serve layer's cross-pattern
        microbatching keys on it); ``"elastic"`` — ``bind(slack=s)``
        executes the bounded-slack macro-step mode (``core.elastic``),
        bitwise-identical to the bulk-synchronous bound; ``"slots"`` —
        persistent device-resident RHS slots on the stacked bank
        (``blank_rhs``/``insert_lane``/``extract_lane``/
        ``solve_resident``; the continuous-batching serve engine,
        ``repro.serve.slots``, requires it); ``"shard-rows"`` —
        ``bind(shard="rows")`` row-partitions one plan across the
        mesh's ``model`` axis with halo exchange instead of per-core
        sharding (``core.rowshard`` / ``solver.rowsharded``)."""
        return ()
