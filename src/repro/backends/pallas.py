"""Pallas backend — the TPU kernel executor behind the ``Backend``
protocol (kernel in ``repro.kernels.sptrsv``, tile padding shared with
``repro.kernels.ops``)."""
from __future__ import annotations

import numpy as np

from repro import obs
from repro.backends.base import (
    Backend,
    BoundSolve,
    expected_entry_count,
    masked_value_gather,
)
from repro.backends.registry import register_backend


class PallasBoundSolve(BoundSolve):
    backend = "pallas"

    def __init__(self, arrays, val_src, diag_src, *, n, n_entries,
                 np_dtype, steps_per_tile, interpret):
        # arrays = (row_ids, col_idx, vals, diag, accum_mask), tile-padded
        self._arrays = arrays
        self._val_src = val_src  # int32[T_pad, k, W] device (-1 padded)
        self._diag_src = diag_src  # int32[T_pad, k] device (-1 padded)
        self.n = n
        self.n_entries = n_entries
        self._np_dtype = np_dtype
        self._steps_per_tile = steps_per_tile
        self._interpret = interpret

    def solve(self, b):
        from repro.kernels.ops import solve_with_kernel_arrays

        return solve_with_kernel_arrays(
            self._arrays, b, n=self.n,
            steps_per_tile=self._steps_per_tile,
            interpret=self._interpret, dtype=self._np_dtype,
        )

    def update_values(self, data: np.ndarray) -> "PallasBoundSolve":
        import jax.numpy as jnp

        with obs.span(
            "backend.update_values", cat="backend", backend=self.backend
        ):
            data = jnp.asarray(
                self._check_data(data).astype(self._np_dtype)
            )
            row_ids, col_idx, vals, diag, accum = self._arrays
            vals, diag = masked_value_gather(
                data, self._val_src, vals, self._diag_src, diag
            )
        return PallasBoundSolve(
            (row_ids, col_idx, vals, diag, accum),
            self._val_src,
            self._diag_src,
            n=self.n,
            n_entries=self.n_entries,
            np_dtype=self._np_dtype,
            steps_per_tile=self._steps_per_tile,
            interpret=self._interpret,
        )

    def describe(self) -> dict:
        T, k = self._arrays[0].shape
        W = self._arrays[1].shape[-1]
        return {
            "backend": self.backend,
            "n": self.n,
            "n_steps": T,  # tile-padded
            "k": k,
            "W": W,
            "dtype": np.dtype(self._np_dtype).name,
            "steps_per_tile": self._steps_per_tile,
            "interpret": bool(self._interpret),
            "device_bytes": int(
                sum(a.size * a.dtype.itemsize
                    for a in self._arrays + (self._val_src, self._diag_src))
            ),
        }


class ElasticPallasBoundSolve(BoundSolve):
    """The ``mode="elastic"`` kernel bound: readiness waves replace the
    per-step level barrier inside each tile (``sptrsv_pallas_elastic``),
    bitwise-identical to ``PallasBoundSolve`` on the same plan."""

    backend = "pallas"

    def __init__(self, arrays, elastic, val_src, diag_src, *, n, n_entries,
                 np_dtype, interpret):
        # arrays = (wave_id, n_waves, row_ids, col_idx, vals, diag,
        #           accum_mask), window-padded; tile size == slack
        self._arrays = arrays
        self._elastic = elastic  # core.elastic.ElasticPlan certificate
        self._val_src = val_src
        self._diag_src = diag_src
        self.n = n
        self.n_entries = n_entries
        self._np_dtype = np_dtype
        self._interpret = interpret
        # runtime side of the elastic certificate (cf. the scan elastic
        # bound): the kernel grid runs exactly n_macro_steps tiles per
        # solve, so a timed solve records that many executed macro-steps
        self._runtime = {"timed_solves": 0, "macro_steps_executed": 0}

    def solve(self, b):
        from repro.kernels.ops import solve_with_elastic_kernel_arrays

        return solve_with_elastic_kernel_arrays(
            self._arrays, b, n=self.n,
            steps_per_tile=self._elastic.slack,
            interpret=self._interpret, dtype=self._np_dtype,
        )

    def solve_timed(self, b):
        """Whole-solve timing (the kernel grid is one dispatch — there
        is no host-visible per-tile boundary), plus the elastic runtime
        bookkeeping ``describe()`` reports against the certificate."""
        x, steps = super().solve_timed(b)
        self._runtime["timed_solves"] += 1
        self._runtime["macro_steps_executed"] += self._elastic.n_macro_steps
        return x, steps

    def update_values(self, data: np.ndarray) -> "ElasticPallasBoundSolve":
        import jax.numpy as jnp

        with obs.span(
            "backend.update_values", cat="backend", backend=self.backend
        ):
            data = jnp.asarray(
                self._check_data(data).astype(self._np_dtype)
            )
            (
                wave_id,
                n_waves,
                row_ids,
                col_idx,
                vals,
                diag,
                accum,
            ) = self._arrays
            vals, diag = masked_value_gather(
                data, self._val_src, vals, self._diag_src, diag
            )
        return ElasticPallasBoundSolve(
            (wave_id, n_waves, row_ids, col_idx, vals, diag, accum),
            self._elastic,
            self._val_src,
            self._diag_src,
            n=self.n,
            n_entries=self.n_entries,
            np_dtype=self._np_dtype,
            interpret=self._interpret,
        )

    def describe(self) -> dict:
        T, k = self._arrays[2].shape
        W = self._arrays[3].shape[-1]
        ep = self._elastic
        cert = ep.stats() if ep is not None else {}
        rt = dict(self._runtime)
        if rt["timed_solves"]:
            rt["macro_steps_per_solve"] = round(
                rt["macro_steps_executed"] / rt["timed_solves"], 2
            )
        return {
            "backend": self.backend,
            "mode": "elastic",
            "n": self.n,
            "n_steps": T,  # window-padded
            "n_macro_steps": ep.n_macro_steps,
            "slack": ep.slack,
            "mean_waves_per_tile": float(ep.n_waves.mean()),
            "k": k,
            "W": W,
            "dtype": np.dtype(self._np_dtype).name,
            "steps_per_tile": ep.slack,
            "interpret": bool(self._interpret),
            "device_bytes": int(
                sum(a.size * a.dtype.itemsize
                    for a in self._arrays + (self._val_src, self._diag_src))
            ),
            "runtime": {
                **rt,
                "predicted_macro_steps": ep.n_macro_steps,
                "predicted_barrier_fusion": cert.get("barrier_fusion"),
                "predicted_step_fusion": cert.get("step_fusion"),
            },
        }


@register_backend
class PallasBackend(Backend):
    """Grid-of-tiles Pallas kernel; x resident in VMEM, plan tensors
    streamed per tile. Interpret mode (CPU) executes the same kernel
    logic through the Pallas interpreter. ``bind(slack=s)`` switches to
    the readiness-wave elastic kernel (``"elastic"`` capability; the
    tile size becomes the slack window)."""

    name = "pallas"

    def capabilities(self):
        return ("elastic",)

    def bind(self, exec_plan, *, dtype=np.float32, steps_per_tile=8,
             interpret=None, mesh=None, slack=0,
             shard="model") -> BoundSolve:
        if shard != "model":
            raise ValueError(
                f"backend='pallas' does not support shard={shard!r} "
                "(no 'shard-rows' capability); use backend='distributed'"
            )
        with obs.span(
            "backend.bind",
            cat="backend",
            backend=self.name,
            n=exec_plan.n,
            slack=slack,
        ):
            return self._bind(
                exec_plan, dtype=dtype, steps_per_tile=steps_per_tile,
                interpret=interpret, slack=slack,
            )

    def _bind(self, exec_plan, *, dtype, steps_per_tile, interpret,
              slack) -> BoundSolve:
        import jax
        import jax.numpy as jnp

        from repro.kernels.ops import _pad_steps, kernel_plan_arrays

        if interpret is None:
            interpret = jax.default_backend() != "tpu"
        assert exec_plan.val_src is not None and exec_plan.diag_src is not None
        if slack > 0:
            from repro.core.elastic import elastic_transform

            ep = exec_plan.elastic
            if ep is None or ep.slack != slack:
                ep = elastic_transform(exec_plan, slack)
            arrays = (
                jnp.asarray(ep.wave_id.reshape(-1), jnp.int32),
                jnp.asarray(ep.n_waves, jnp.int32),
                *kernel_plan_arrays(exec_plan, steps_per_tile=slack,
                                    dtype=dtype),
            )
            val_src = _pad_steps(exec_plan.val_src, slack, -1)
            diag_src = _pad_steps(exec_plan.diag_src, slack, -1)
            return ElasticPallasBoundSolve(
                arrays,
                ep,
                jnp.asarray(val_src, jnp.int32),
                jnp.asarray(diag_src, jnp.int32),
                n=exec_plan.n,
                n_entries=expected_entry_count(exec_plan),
                np_dtype=np.dtype(dtype),
                interpret=interpret,
            )
        arrays = kernel_plan_arrays(
            exec_plan, steps_per_tile=steps_per_tile, dtype=dtype
        )
        # source maps ride the same tile padding; -1 marks padding slots so
        # device-side refreshes leave them untouched
        val_src = _pad_steps(exec_plan.val_src, steps_per_tile, -1)
        diag_src = _pad_steps(exec_plan.diag_src, steps_per_tile, -1)
        return PallasBoundSolve(
            arrays,
            jnp.asarray(val_src, jnp.int32),
            jnp.asarray(diag_src, jnp.int32),
            n=exec_plan.n,
            n_entries=expected_entry_count(exec_plan),
            np_dtype=np.dtype(dtype),
            steps_per_tile=steps_per_tile,
            interpret=interpret,
        )
