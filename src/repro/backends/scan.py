"""Scan backend — the single-chip `lax.scan` executor behind the
``Backend`` protocol (device work in ``repro.solver.executor``)."""
from __future__ import annotations

import numpy as np

from repro.backends.base import (
    Backend,
    BoundSolve,
    expected_entry_count,
    masked_value_gather,
)
from repro.backends.registry import register_backend


class ScanBoundSolve(BoundSolve):
    backend = "scan"
    # the scan trace reads only the plan tensor shapes (step_bounds never
    # enter it), so structurally-identical plans can share one vmapped
    # dispatch — the serve layer's width-class cross-pattern batching
    supports_grouped = True

    def __init__(self, pa, val_src, diag_src, np_dtype, n_entries):
        self._pa = pa  # solver.executor.PlanArrays (device-resident)
        self._val_src = val_src  # int32[T, k, W] device
        self._diag_src = diag_src  # int32[T, k] device
        self._np_dtype = np_dtype
        self.n = pa.n
        self.n_entries = n_entries

    def solve(self, b):
        from repro.solver.executor import solve_with_plan

        return solve_with_plan(self._pa, b)

    @classmethod
    def solve_grouped(cls, bounds, b_cols):
        from repro.solver.executor import solve_with_plan_group

        return solve_with_plan_group([b._pa for b in bounds], b_cols)

    @classmethod
    def stack_bank(cls, bounds, perms, invs):
        from repro.solver.executor import stack_plan_bank

        return stack_plan_bank([b._pa for b in bounds], perms, invs)

    @classmethod
    def solve_bank(cls, bank, lane_idx, B):
        from repro.solver.executor import solve_with_bank

        return solve_with_bank(bank, lane_idx, B)

    def update_values(self, data: np.ndarray) -> "ScanBoundSolve":
        import jax.numpy as jnp

        data = jnp.asarray(self._check_data(data).astype(self._np_dtype))
        vals, diag = masked_value_gather(
            data, self._val_src, self._pa.vals, self._diag_src, self._pa.diag
        )
        new = ScanBoundSolve(
            self._pa._replace(vals=vals, diag=diag),
            self._val_src,  # index tensors shared, read-only
            self._diag_src,
            self._np_dtype,
            self.n_entries,
        )
        return new

    def describe(self) -> dict:
        T, k = self._pa.row_ids.shape
        W = self._pa.col_idx.shape[-1]
        return {
            "backend": self.backend,
            "n": self.n,
            "n_steps": T,
            "k": k,
            "W": W,
            "dtype": np.dtype(self._np_dtype).name,
            "device_bytes": int(
                sum(a.size * a.dtype.itemsize
                    for a in self._pa[:5] + (self._val_src, self._diag_src))
            ),
        }


@register_backend
class ScanBackend(Backend):
    """One `lax.scan` over the plan; superstep barriers are free on a
    single chip, so `step_bounds` is ignored here."""

    name = "scan"

    def capabilities(self):
        return ("grouped",)

    def bind(self, exec_plan, *, dtype=np.float32, steps_per_tile=8,
             interpret=None, mesh=None) -> ScanBoundSolve:
        import jax.numpy as jnp

        from repro.solver.executor import plan_arrays

        del steps_per_tile, interpret, mesh  # scan has no tiling or mesh
        pa = plan_arrays(exec_plan, dtype=dtype)
        assert exec_plan.val_src is not None and exec_plan.diag_src is not None
        return ScanBoundSolve(
            pa,
            jnp.asarray(exec_plan.val_src, jnp.int32),
            jnp.asarray(exec_plan.diag_src, jnp.int32),
            np.dtype(dtype),
            expected_entry_count(exec_plan),
        )
