"""Scan backend — the single-chip `lax.scan` executor behind the
``Backend`` protocol (device work in ``repro.solver.executor``)."""
from __future__ import annotations

import numpy as np

from repro import obs
from repro.backends.base import (
    Backend,
    BoundSolve,
    expected_entry_count,
    masked_value_gather,
)
from repro.backends.registry import register_backend


class ScanBoundSolve(BoundSolve):
    backend = "scan"
    # the scan trace reads only the plan tensor shapes (step_bounds never
    # enter it), so structurally-identical plans can share one vmapped
    # dispatch — the serve layer's width-class cross-pattern batching
    supports_grouped = True

    def __init__(self, pa, val_src, diag_src, np_dtype, n_entries):
        self._pa = pa  # solver.executor.PlanArrays (device-resident)
        self._val_src = val_src  # int32[T, k, W] device
        self._diag_src = diag_src  # int32[T, k] device
        self._np_dtype = np_dtype
        self.n = pa.n
        self.n_entries = n_entries

    def solve(self, b):
        from repro.solver.executor import solve_with_plan

        return solve_with_plan(self._pa, b)

    def solve_timed(self, b):
        """Per-superstep timed solve: one jitted segment per superstep
        of the plan (see ``solver.executor.solve_with_plan_timed``)."""
        from repro.solver.executor import solve_with_plan_timed

        return solve_with_plan_timed(self._pa, b)

    @classmethod
    def solve_grouped(cls, bounds, b_cols):
        from repro.solver.executor import solve_with_plan_group

        return solve_with_plan_group([b._pa for b in bounds], b_cols)

    @classmethod
    def stack_bank(cls, bounds, perms, invs):
        from repro.solver.executor import stack_plan_bank

        return stack_plan_bank([b._pa for b in bounds], perms, invs)

    @classmethod
    def solve_bank(cls, bank, lane_idx, B):
        from repro.solver.executor import solve_with_bank

        return solve_with_bank(bank, lane_idx, B)

    # resident RHS slots ("slots" capability) — the continuous-batching
    # serve engine's device contract, all thin wrappers over the jitted
    # executor ops (one compiled variant per (n, S) shape)
    @classmethod
    def blank_rhs(cls, n, slots, dtype):
        from repro.solver.executor import blank_rhs

        return blank_rhs(n, slots, dtype)

    @classmethod
    def insert_lane(cls, B_res, lane, b):
        from repro.solver.executor import insert_lane

        return insert_lane(B_res, lane, b)

    @classmethod
    def extract_lane(cls, X, lane):
        from repro.solver.executor import extract_lane

        return extract_lane(X, lane)

    @classmethod
    def solve_resident(cls, bank, lane_idx, B_res):
        from repro.solver.executor import solve_resident

        return solve_resident(bank, lane_idx, B_res)

    def update_values(self, data: np.ndarray) -> "ScanBoundSolve":
        import jax.numpy as jnp

        with obs.span(
            "backend.update_values", cat="backend", backend=self.backend
        ):
            data = jnp.asarray(
                self._check_data(data).astype(self._np_dtype)
            )
            vals, diag = masked_value_gather(
                data,
                self._val_src,
                self._pa.vals,
                self._diag_src,
                self._pa.diag,
            )
        new = ScanBoundSolve(
            self._pa._replace(vals=vals, diag=diag),
            self._val_src,  # index tensors shared, read-only
            self._diag_src,
            self._np_dtype,
            self.n_entries,
        )
        return new

    def describe(self) -> dict:
        T, k = self._pa.row_ids.shape
        W = self._pa.col_idx.shape[-1]
        return {
            "backend": self.backend,
            "n": self.n,
            "n_steps": T,
            "k": k,
            "W": W,
            "dtype": np.dtype(self._np_dtype).name,
            "device_bytes": int(
                sum(a.size * a.dtype.itemsize
                    for a in self._pa[:5] + (self._val_src, self._diag_src))
            ),
        }


class ElasticScanBoundSolve(BoundSolve):
    """The ``mode="elastic"`` scan bound: ``ceil(T / slack)`` fused
    macro-steps instead of T scan steps (``core.elastic``), bitwise-
    identical to ``ScanBoundSolve`` on the same plan."""

    backend = "scan"
    # the macro-step tensors bake the slack window into the trace shape
    # and the elastic bound has no banked/grouped twin — width-class
    # grouping stays on the bulk-synchronous bound
    supports_grouped = False

    def __init__(self, ea, elastic, val_src, diag_src, np_dtype, n_entries):
        self._ea = ea  # solver.executor.ElasticArrays (device-resident)
        self._elastic = elastic  # core.elastic.ElasticPlan certificate
        self._val_src = val_src  # int32[M, S, k, W] device (-1 padded)
        self._diag_src = diag_src  # int32[M, S, k] device (-1 padded)
        self._np_dtype = np_dtype
        self.n = ea.n
        self.n_entries = n_entries
        # runtime side of the elastic certificate: what timed solves
        # actually executed, reported by describe() next to the
        # certificate's predicted fusion ratios (fresh per bound; an
        # update_values swap starts a new runtime history)
        self._runtime = {"timed_solves": 0, "macro_steps_executed": 0}

    def solve(self, b):
        from repro.solver.executor import solve_with_elastic

        return solve_with_elastic(self._ea, b)

    def solve_timed(self, b):
        """Per-macro-step timed elastic solve; records the actual
        macro-step count into the bound's runtime telemetry so
        ``describe()`` can put measured execution next to the
        certificate's predicted ``barrier_fusion``."""
        from repro.solver.executor import solve_with_elastic_timed

        x, steps = solve_with_elastic_timed(self._ea, b)
        self._runtime["timed_solves"] += 1
        self._runtime["macro_steps_executed"] += len(steps)
        return x, steps

    def update_values(self, data: np.ndarray) -> "ElasticScanBoundSolve":
        import jax.numpy as jnp

        with obs.span(
            "backend.update_values", cat="backend", backend=self.backend
        ):
            data = jnp.asarray(
                self._check_data(data).astype(self._np_dtype)
            )
            vals, diag = masked_value_gather(
                data,
                self._val_src,
                self._ea.vals,
                self._diag_src,
                self._ea.diag,
            )
        return ElasticScanBoundSolve(
            self._ea._replace(vals=vals, diag=diag),
            self._elastic,
            self._val_src,  # index tensors shared, read-only
            self._diag_src,
            self._np_dtype,
            self.n_entries,
        )

    def describe(self) -> dict:
        M, S, k = self._ea.row_ids.shape
        W = self._ea.col_idx.shape[-1]
        cert = self._elastic.stats() if self._elastic is not None else {}
        rt = dict(self._runtime)
        if rt["timed_solves"]:
            rt["macro_steps_per_solve"] = round(
                rt["macro_steps_executed"] / rt["timed_solves"], 2
            )
        return {
            "backend": self.backend,
            "mode": "elastic",
            "n": self.n,
            "n_steps": self._ea.n_steps,
            "n_macro_steps": M,
            "slack": S,
            "k": k,
            "W": W,
            "dtype": np.dtype(self._np_dtype).name,
            "device_bytes": int(
                sum(a.size * a.dtype.itemsize
                    for a in self._ea[:5] + (self._val_src, self._diag_src))
            ),
            # certificate (predicted) vs runtime (measured, from
            # solve_timed): the elastic fused-barrier claim, executed
            "runtime": {
                **rt,
                "predicted_macro_steps": M,
                "predicted_barrier_fusion": cert.get("barrier_fusion"),
                "predicted_step_fusion": cert.get("step_fusion"),
            },
        }


@register_backend
class ScanBackend(Backend):
    """One `lax.scan` over the plan; superstep barriers are free on a
    single chip, so `step_bounds` is ignored here. ``bind(slack=s)``
    switches to the elastic macro-step executor (``"elastic"``
    capability)."""

    name = "scan"

    def capabilities(self):
        return ("grouped", "elastic", "slots")

    def bind(self, exec_plan, *, dtype=np.float32, steps_per_tile=8,
             interpret=None, mesh=None, slack=0,
             shard="model") -> BoundSolve:
        if shard != "model":
            raise ValueError(
                f"backend='scan' does not support shard={shard!r} "
                "(no 'shard-rows' capability); use backend='distributed'"
            )
        with obs.span(
            "backend.bind",
            cat="backend",
            backend=self.name,
            n=exec_plan.n,
            slack=slack,
        ):
            return self._bind(exec_plan, dtype=dtype, slack=slack)

    def _bind(self, exec_plan, *, dtype, slack) -> BoundSolve:
        import jax.numpy as jnp

        from repro.solver.executor import plan_arrays

        assert exec_plan.val_src is not None and exec_plan.diag_src is not None
        if slack > 0:
            from repro.core.elastic import elastic_transform
            from repro.solver.executor import (
                _pad_to_window,
                elastic_plan_arrays,
            )

            ep = exec_plan.elastic
            if ep is None or ep.slack != slack:
                ep = elastic_transform(exec_plan, slack)
            ea = elastic_plan_arrays(exec_plan, slack=slack, dtype=dtype)
            M, S = ea.row_ids.shape[:2]
            pad = M * S - exec_plan.n_steps
            # source maps ride the same window padding; -1 marks padding
            # so device-side refreshes leave those slots untouched
            val_src = _pad_to_window(exec_plan.val_src, pad, -1)
            diag_src = _pad_to_window(exec_plan.diag_src, pad, -1)
            return ElasticScanBoundSolve(
                ea,
                ep,
                jnp.asarray(val_src.reshape(M, S, *val_src.shape[1:]),
                            jnp.int32),
                jnp.asarray(diag_src.reshape(M, S, *diag_src.shape[1:]),
                            jnp.int32),
                np.dtype(dtype),
                expected_entry_count(exec_plan),
            )
        pa = plan_arrays(exec_plan, dtype=dtype)
        return ScanBoundSolve(
            pa,
            jnp.asarray(exec_plan.val_src, jnp.int32),
            jnp.asarray(exec_plan.diag_src, jnp.int32),
            np.dtype(dtype),
            expected_entry_count(exec_plan),
        )
