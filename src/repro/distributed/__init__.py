from repro.distributed.meshes import (
    batch_axes,
    resolve_spec,
    shardings_for,
    logical_to_shardings,
)

__all__ = [
    "batch_axes",
    "resolve_spec",
    "shardings_for",
    "logical_to_shardings",
]
