"""Fault-tolerance runtime: failure detection, elastic re-meshing,
straggler mitigation. The container has one host, so hardware events are
injected through ``FailureSimulator`` — the decision logic (what the
coordinator does) is the real, tested artifact; the signals are simulated.

Runbook encoded here (1000-node posture):
  * heartbeat miss / step-time blowup  -> mark node suspect
  * suspect node persists              -> declare failed, trigger elastic
    restart: shrink the data axis to the largest full multiple available,
    rebuild the mesh, restore the latest checkpoint WITH resharding
    (checkpoint.restore_checkpoint(shardings=...)), resume from the
    deterministic data pipeline at the saved step
  * stragglers (p99 >> median)         -> quarantine list; schedule around
    (data-parallel ranks are interchangeable — quarantined ranks get no
    shard on the next re-mesh)
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Tuple

import numpy as np


@dataclasses.dataclass
class NodeState:
    node_id: int
    last_heartbeat: float
    step_times: List[float] = dataclasses.field(default_factory=list)
    suspect: bool = False
    failed: bool = False


@dataclasses.dataclass
class FleetMonitor:
    """Tracks heartbeats + step-time telemetry; decides failures and
    stragglers."""

    n_nodes: int
    heartbeat_timeout_s: float = 10.0
    straggler_factor: float = 2.0
    window: int = 20

    def __post_init__(self):
        now = time.monotonic()
        self.nodes: Dict[int, NodeState] = {
            i: NodeState(i, now) for i in range(self.n_nodes)
        }

    def heartbeat(self, node_id: int, step_time_s: Optional[float] = None,
                  now: Optional[float] = None):
        n = self.nodes[node_id]
        n.last_heartbeat = now if now is not None else time.monotonic()
        if step_time_s is not None:
            n.step_times.append(step_time_s)
            n.step_times = n.step_times[-self.window:]

    def sweep(self, now: Optional[float] = None) -> dict:
        """-> {"failed": [...], "stragglers": [...]}; idempotent."""
        now = now if now is not None else time.monotonic()
        failed, stragglers = [], []
        medians = [
            float(np.median(n.step_times))
            for n in self.nodes.values()
            if n.step_times and not n.failed
        ]
        fleet_median = float(np.median(medians)) if medians else None
        for n in self.nodes.values():
            if n.failed:
                failed.append(n.node_id)
                continue
            if now - n.last_heartbeat > self.heartbeat_timeout_s:
                if n.suspect:
                    n.failed = True
                    failed.append(n.node_id)
                else:
                    n.suspect = True
            else:
                n.suspect = False
            if (
                fleet_median
                and n.step_times
                and float(np.median(n.step_times))
                > self.straggler_factor * fleet_median
            ):
                stragglers.append(n.node_id)
        return {"failed": failed, "stragglers": stragglers,
                "healthy": self.healthy_count()}

    def healthy_count(self) -> int:
        return sum(1 for n in self.nodes.values() if not n.failed)


def elastic_mesh_shape(
    healthy_chips: int, *, model: int = 16, pod: Optional[int] = None
) -> Tuple[dict, int]:
    """Largest (data, model[, pod]) mesh that fits the surviving chips.
    The model axis is sacred (TP degree is baked into layouts); the data
    axis shrinks; pods drop whole when a pod loses its last full data row.
    Returns (mesh shape dict, chips used)."""
    per_pod = healthy_chips if pod is None else healthy_chips // pod
    data = max(per_pod // model, 1)
    if pod is None:
        shape = {"data": data, "model": model}
        return shape, data * model
    shape = {"pod": pod, "data": data, "model": model}
    return shape, pod * data * model


@dataclasses.dataclass
class FailureSimulator:
    """Drives FleetMonitor with injected events (the CPU-container stand-in
    for real hardware signals)."""

    monitor: FleetMonitor
    rng_seed: int = 0

    def kill(self, node_id: int, at: float):
        # stop heartbeats by backdating the last one
        self.monitor.nodes[node_id].last_heartbeat = (
            at - 2 * self.monitor.heartbeat_timeout_s
        )

    def slow_down(self, node_id: int, factor: float, base_step: float = 1.0):
        n = self.monitor.nodes[node_id]
        n.step_times = [base_step * factor] * self.monitor.window


def recovery_plan(
    monitor: FleetMonitor,
    chips_per_node: int,
    *,
    model: int = 16,
    pod: Optional[int] = None,
) -> dict:
    """The coordinator's decision: new mesh + what to do with stragglers."""
    sweep = monitor.sweep()
    healthy_chips = sweep["healthy"] * chips_per_node
    mesh_shape, used = elastic_mesh_shape(healthy_chips, model=model, pod=pod)
    return {
        "mesh_shape": mesh_shape,
        "chips_used": used,
        "quarantine": sweep["stragglers"],
        "lost_nodes": sweep["failed"],
        "action": "restart_from_checkpoint" if sweep["failed"] else (
            "rebalance" if sweep["stragglers"] else "none"
        ),
    }
