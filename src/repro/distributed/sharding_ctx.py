"""Ambient sharding-constraint context.

Model code is mesh-agnostic; the launcher installs a context with the mesh
and the activation rules, and model code calls ``constrain(x, role)`` at the
few load-bearing points (residual stream, microbatch inputs, logits).
Outside any context (unit tests, single device) it is a no-op.

Roles:
  residual   [B, S, D]  -> P(batch, *residual_extra)  (seq-sharding lever)
  tokens     [B, S]     -> P(batch, None)
  logits     [B, S, V]  -> P(batch, None, 'model')
  microbatch [M, B, ...]-> P(None, batch, ...)
"""
from __future__ import annotations

import contextlib
import contextvars
from typing import Optional

import jax
from jax.sharding import PartitionSpec as P

_CTX: contextvars.ContextVar[Optional[dict]] = contextvars.ContextVar(
    "sharding_rules", default=None
)


@contextlib.contextmanager
def activation_sharding(mesh, *, seq_sharded: bool = False):
    from repro.distributed.meshes import batch_axes

    b = batch_axes(mesh)
    batch = b if b else None
    seq = "model" if (seq_sharded and "model" in mesh.axis_names) else None
    rules = {
        "residual": P(batch, seq, None),
        "tokens": P(batch, None),
        "logits": P(batch, None, "model" if "model" in mesh.axis_names else None),
        "microbatch_tokens": P(None, batch, None),
        "decode_batch": P(batch),
    }
    token = _CTX.set(rules)
    try:
        yield
    finally:
        _CTX.reset(token)


def constrain(x: jax.Array, role: str) -> jax.Array:
    rules = _CTX.get()
    if rules is None or role not in rules:
        return x
    spec = rules[role]
    # trim the spec to the rank of x (decode tensors drop the seq dim)
    entries = list(spec)[: x.ndim]
    entries += [None] * (x.ndim - len(entries))
    try:
        return jax.lax.with_sharding_constraint(x, P(*entries))
    except Exception:  # no ambient mesh — leave unconstrained
        return x
