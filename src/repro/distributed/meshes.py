"""Logical-axis -> physical-mesh sharding resolution.

Model code declares logical axes ('tp', 'fsdp', 'batch', None); this module
maps them onto whatever mesh is in play:

  single pod  (data=16, model=16):  tp->'model', fsdp->'data', batch->('data',)
  multi-pod   (pod=2, data=16, model=16): batch->('pod','data'); params stay
              FSDP-sharded *within* a pod and replicated across pods (the
              cross-pod hop only carries gradient all-reduces — DCN-friendly).

Divisibility guard: a logical axis is dropped (replicated) for a dimension
the mesh cannot divide evenly — e.g. 8 kv-heads over 16 'model' devices.
Model code places 'tp' on the widest safe dimension, so this is a safety
net, not the primary mechanism.
"""
from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

Pytree = Any


def batch_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def _physical(mesh: Mesh, logical: Optional[str]):
    if logical is None:
        return None
    if logical == "tp":
        return "model" if "model" in mesh.axis_names else None
    if logical == "fsdp":
        return "data" if "data" in mesh.axis_names else None
    if logical == "batch":
        ax = batch_axes(mesh)
        return ax if ax else None
    raise ValueError(f"unknown logical axis {logical!r}")


def _axis_size(mesh: Mesh, phys) -> int:
    if phys is None:
        return 1
    if isinstance(phys, tuple):
        out = 1
        for a in phys:
            out *= mesh.shape[a]
        return out
    return mesh.shape[phys]


def resolve_spec(
    mesh: Mesh, logical: Tuple[Optional[str], ...], shape: Tuple[int, ...]
) -> P:
    """PartitionSpec for one array, dropping non-divisible placements."""
    entries = []
    for dim, log in zip(shape, logical):
        phys = _physical(mesh, log)
        if phys is not None and dim % _axis_size(mesh, phys) == 0:
            entries.append(phys)
        else:
            entries.append(None)
    # trailing Nones are implicit
    while entries and entries[-1] is None:
        entries.pop()
    return P(*entries)


def shardings_for(mesh: Mesh, logical_tree: Pytree, shape_tree: Pytree) -> Pytree:
    """NamedSharding tree for (logical specs, matching shapes)."""
    is_spec = lambda x: isinstance(x, tuple) and all(  # noqa: E731
        (isinstance(e, str) or e is None) for e in x
    )
    return jax.tree_util.tree_map(
        lambda log, arr: NamedSharding(
            mesh, resolve_spec(mesh, log, tuple(arr.shape))
        ),
        logical_tree,
        shape_tree,
        is_leaf=is_spec,
    )


def logical_to_shardings(mesh: Mesh, logical_tree: Pytree, abstract: Pytree) -> Pytree:
    return shardings_for(mesh, logical_tree, abstract)


def activation_spec(mesh: Mesh, *, seq_sharded: bool = False) -> P:
    """[B, S, D] residual-stream spec; seq_sharded=True = Megatron-SP style
    (sequence over 'model' between blocks — the remat-memory lever)."""
    b = batch_axes(mesh) or None
    if seq_sharded and "model" in mesh.axis_names:
        return P(b, "model", None)
    return P(b, None, None)
