"""Error-feedback gradient compression for the cross-pod hop.

At 2+ pods the gradient all-reduce crosses DCN (slow, ~10x less bandwidth
than ICI). We compress gradients to int8 with per-block scales before that
hop and keep the quantization residual in an error-feedback buffer
(Karimireddy et al.-style EF-SGD): the residual is added back the next step,
so compression bias does not accumulate and convergence is preserved
(tests/test_distributed_extras.py trains through it).

``compressed_grad_transform`` plugs into ``make_train_step(grad_transform=…)``:
the quantize/dequantize pair is algebraically a no-op + bounded noise, so
the same code is correct on any mesh while modeling the wire format; the
int8 tensor is what would cross DCN (4x fewer bytes, visible in the HLO of
the multi-pod dry-run when enabled via REPRO_COMPRESS_GRADS=1).
"""
from __future__ import annotations

from typing import Any, Callable, Tuple

import jax
import jax.numpy as jnp

Pytree = Any

BLOCK = 256


def quantize_int8(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Per-block symmetric int8 quantization. x: any shape (flattened)."""
    flat = x.reshape(-1)
    pad = (-flat.shape[0]) % BLOCK
    flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, BLOCK)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def dequantize_int8(q: jax.Array, scale: jax.Array, shape, size) -> jax.Array:
    flat = (q.astype(jnp.float32) * scale).reshape(-1)[:size]
    return flat.reshape(shape)


def ef_compress_tree(grads: Pytree, error: Pytree) -> Tuple[Pytree, Pytree]:
    """(compressed-then-decompressed grads, new error buffers)."""

    def one(g, e):
        g32 = g.astype(jnp.float32) + e
        q, s = quantize_int8(g32)
        deq = dequantize_int8(q, s, g32.shape, g32.size)
        return deq.astype(g.dtype), (g32 - deq)

    flat_g, tdef = jax.tree_util.tree_flatten(grads)
    flat_e = jax.tree_util.tree_leaves(error)
    outs = [one(g, e) for g, e in zip(flat_g, flat_e)]
    new_g = jax.tree_util.tree_unflatten(tdef, [o[0] for o in outs])
    new_e = jax.tree_util.tree_unflatten(tdef, [o[1] for o in outs])
    return new_g, new_e


def init_error_buffers(params: Pytree) -> Pytree:
    return jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params
    )


def compressed_grad_transform(error_state: dict) -> Callable[[Pytree], Pytree]:
    """Stateful-through-closure variant for simple loops (tests/examples).
    ``error_state['e']`` holds the EF buffers and is updated in place."""

    def transform(grads: Pytree) -> Pytree:
        new_g, new_e = ef_compress_tree(grads, error_state["e"])
        error_state["e"] = new_e
        return new_g

    return transform
