"""Paper Table 7.7 — block-parallel scheduling: scheduling-time speed-up,
solve-cost ratio and superstep growth vs number of scheduling blocks."""
from __future__ import annotations

import time

from benchmarks.common import (
    K_CORES,
    bsp_cost,
    dag_from_lower_csr,
    dataset,
    geomean,
    schedule,
)

BLOCKS = (1, 2, 4, 8, 16)


def run(csv_rows):
    print("# Table 7.7 — block-parallel scheduling (vs 1 block)")
    print("# single-core container: python-thread sched_speedup is GIL-bound;")
    print("# the paper's superlinear speedup needs real cores. cost_ratio and")
    print("# superstep growth (the schedule-quality trade) reproduce.")
    print(f"{'blocks':>6s} {'sched_speedup':>13s} {'cost_ratio':>10s} "
          f"{'superstep_x':>11s}")
    mats = dataset("suitesparse") + dataset("ichol")
    base_t, base_cost, base_ss = {}, {}, {}
    for mname, L in mats:
        dag = dag_from_lower_csr(L)
        t0 = time.perf_counter()
        s = schedule(dag, K_CORES, strategy="growlocal")
        base_t[mname] = time.perf_counter() - t0
        base_cost[mname] = bsp_cost(dag, s)
        base_ss[mname] = s.n_supersteps
    for nb in BLOCKS:
        sp, cr, ssx = [], [], []
        for mname, L in mats:
            dag = dag_from_lower_csr(L)
            t0 = time.perf_counter()
            s = schedule(dag, K_CORES, strategy="block", n_blocks=nb)
            t = time.perf_counter() - t0
            sp.append(base_t[mname] / t)
            cr.append(bsp_cost(dag, s) / base_cost[mname])
            ssx.append(s.n_supersteps / max(base_ss[mname], 1))
        print(f"{nb:6d} {geomean(sp):13.2f} {geomean(cr):10.3f} "
              f"{geomean(ssx):11.2f}")
        csv_rows.append((f"t78.blocks{nb}.sched_speedup", round(geomean(sp), 3),
                         f"cost_ratio={geomean(cr):.3f}"))
