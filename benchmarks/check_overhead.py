"""Verifier-overhead benchmark — ``repro.analysis`` vs the inspector.

A verifier that doubles inspection time never gets turned on.  This
driver times the static passes against ``compile_plan`` (the dominant
inspector stage the verifier re-audits) on the inspector_bench families
at N in {1e4, 1e5}:

  * **fast** — the default ``validate="fast"`` invariant set (schedule
    race detect + reorder audit + plan sanitizer + lane layout), the
    thing meant to ride along on every build;
  * **full** — adds value provenance and load accounting; bounded but
    not gated (it is the slow/CI depth).

Acceptance (ISSUE 10): fast <= 15% of ``compile_plan`` time at N=1e5.

Output: human table + ``repro-bench-rows/v1`` JSON (``--json``), the
same schema as the other benchmark drivers.

  PYTHONPATH=src:. python -m benchmarks.check_overhead --json chk.json
  PYTHONPATH=src:. python -m benchmarks.check_overhead --smoke  # CI:
      N=1e4 rows only + the acceptance ratio check at that size
"""
from __future__ import annotations

import argparse
import sys
import time

import numpy as np

from benchmarks.common import write_json_rows
from repro.analysis import Artifacts, verify_artifacts
from repro.autotune import scale_corpus_entry
from repro.core.plan import compile_plan
from repro.core.reorder import apply_reordering
from repro.pipeline import schedule
from repro.sparse import (
    dag_from_lower_csr,
    erdos_renyi_lower,
    narrow_band_lower,
)

K = 8
ACCEPT_RATIO = 0.15  # fast verify / compile_plan, at N=1e5 (the gate)
SMOKE_RATIO = 0.30  # N=1e4 CI sanity bound: fixed per-call overhead
#                     dominates at small N, so the 1e5 budget is not
#                     representative there

FAMILIES = {
    "er_sparse": {
        10_000: lambda: erdos_renyi_lower(10_000, 0.002 * 800 / 10_000,
                                          seed=201),
        100_000: scale_corpus_entry("er_sparse_100k").make,
    },
    "band_narrow": {
        10_000: lambda: narrow_band_lower(10_000, 0.14, 10, seed=203),
        100_000: scale_corpus_entry("band_narrow_100k").make,
    },
}


def _median_time(fn, reps: int) -> float:
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        fn()
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def _bench_point(name: str, L0, *, reps: int) -> dict:
    dag = dag_from_lower_csr(L0)
    s0 = schedule(dag, K, strategy="growlocal")
    L, s, _, r = apply_reordering(L0, s0)
    plan = compile_plan(L, s)
    art = Artifacts(L=L, sched=s, plan=plan, perm=r.perm, sched_pre=s0)

    rep = verify_artifacts(art, level="full")  # warm + correctness gate
    if not rep.ok:
        raise SystemExit(
            f"check_overhead FAILED: verifier flagged a pristine plan "
            f"({name}): {rep.codes()}"
        )

    t_compile = _median_time(lambda: compile_plan(L, s), reps)
    t_fast = _median_time(
        lambda: verify_artifacts(art, level="fast"), reps
    )
    t_full = _median_time(
        lambda: verify_artifacts(art, level="full"), max(reps - 1, 1)
    )
    return {
        "name": name,
        "n": L.n_rows,
        "nnz": L.nnz,
        "compile_seconds": t_compile,
        "verify_fast_seconds": t_fast,
        "verify_full_seconds": t_full,
        "fast_ratio": t_fast / t_compile,
        "full_ratio": t_full / t_compile,
    }


def run(csv_rows, *, smoke: bool = False) -> dict:
    sizes = (10_000,) if smoke else (10_000, 100_000)
    print(
        f"# check_overhead — static verifier vs compile_plan, k={K}, "
        f"growlocal ({'smoke: N=1e4 only' if smoke else 'full'})"
    )
    print(
        f"{'matrix':22s} {'nnz':>9s} {'compile ms':>11s} {'fast ms':>9s} "
        f"{'full ms':>9s} {'fast/comp':>10s} {'full/comp':>10s}"
    )
    out = {}
    gate_ratios = []
    for fam, points in FAMILIES.items():
        for n in sizes:
            L = points[n]()
            tag = f"{fam}.{n // 1000}k"
            r = _bench_point(tag, L, reps=5)
            out[tag] = r
            if n == max(sizes):
                gate_ratios.append(r["fast_ratio"])
            print(
                f"{tag:22s} {r['nnz']:9d} "
                f"{r['compile_seconds']*1e3:11.1f} "
                f"{r['verify_fast_seconds']*1e3:9.1f} "
                f"{r['verify_full_seconds']*1e3:9.1f} "
                f"{r['fast_ratio']:9.1%} {r['full_ratio']:9.1%}"
            )
            csv_rows.append(
                (f"analysis.{tag}.verify_fast",
                 round(r["verify_fast_seconds"] * 1e6, 1),
                 round(r["fast_ratio"], 4))
            )
            csv_rows.append(
                (f"analysis.{tag}.verify_full",
                 round(r["verify_full_seconds"] * 1e6, 1),
                 round(r["full_ratio"], 4))
            )
            csv_rows.append(
                (f"analysis.{tag}.compile",
                 round(r["compile_seconds"] * 1e6, 1), 1.0)
            )
    worst = max(gate_ratios)
    budget = SMOKE_RATIO if smoke else ACCEPT_RATIO
    ok = worst <= budget
    size_tag = f"{max(sizes) // 1000}k"
    print(
        f"acceptance at N={size_tag} (fast <= {budget:.0%} of "
        f"compile_plan): {'PASS' if ok else 'MISS'} (worst {worst:.1%})"
    )
    out["accept_fast_ratio"] = bool(ok)
    if not ok:
        raise SystemExit(
            f"check_overhead FAILED: fast verify is {worst:.1%} of "
            f"compile_plan at N={size_tag} (budget {budget:.0%})"
        )
    return out


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--json", metavar="PATH", default=None)
    ap.add_argument(
        "--smoke", action="store_true",
        help="short CI run: N=1e4 rows only; still gates on the fast "
        "ratio (exits non-zero on overrun)",
    )
    args = ap.parse_args(argv)
    csv_rows = []
    out = run(csv_rows, smoke=args.smoke)
    print("\n# CSV: name,us_per_call,derived")
    for name, val, derived in csv_rows:
        print(f"{name},{val},{derived}")
    if args.json:
        write_json_rows(args.json, csv_rows, ["analysis"], analysis=out)


if __name__ == "__main__":
    main(sys.argv[1:])
