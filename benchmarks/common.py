"""Shared benchmark infrastructure.

Data sets mirror the paper's §6.2 at container scale (documented scaling:
N=12,000 instead of 100,000–4M; the schedulers are O(|E| log |V|) and the
executors O(nnz), so relative results carry). Matrices are cached per
process. Wall-clock timing follows §6.1: two warm-up runs, then the median
of repeated timed runs.
"""
from __future__ import annotations

import time
from functools import lru_cache
from typing import Callable, Dict, List, Tuple

import numpy as np

from repro.core import DEFAULT_L, bsp_cost, check_validity, serial_schedule
from repro.pipeline import TriangularSolver, schedule
from repro.sparse import (
    dag_from_lower_csr,
    erdos_renyi_lower,
    ichol0,
    narrow_band_lower,
    poisson2d_matrix,
    poisson3d_matrix,
)
from repro.sparse.csr import lower_triangle_of

N_SCALE = 12_000  # paper uses 100k for random sets; scaled for the container
K_CORES = 8

# display name -> pipeline registry strategy; all benchmark drivers schedule
# through repro.pipeline so they exercise the same code path as production.
STRATEGY_OF: Dict[str, str] = {
    "GrowLocal": "growlocal",
    "Funnel+GL": "funnel-gl",
    "SpMP-like": "spmp",
    "HDagg": "hdagg",
    "Wavefront": "wavefront",
}

SCHEDULERS: Dict[str, Callable] = {
    name: (lambda d, k, _s=strat: schedule(d, k, strategy=_s))
    for name, strat in STRATEGY_OF.items()
}


@lru_cache(maxsize=None)
def dataset(name: str):
    """-> list of (matrix_name, lower CSR). Mirrors §6.2 families."""
    if name == "suitesparse":  # FEM stand-ins (§6.2.1 substitute)
        mats = {
            "poisson2d_110": lower_triangle_of(poisson2d_matrix(110)),
            "poisson3d_23": lower_triangle_of(poisson3d_matrix(23)),
            "band2d_mixed": lower_triangle_of(poisson2d_matrix(155, 78)),
        }
    elif name == "ichol":  # §6.2.3
        mats = {
            "poisson2d_90_iCh": ichol0(poisson2d_matrix(90)),
            "poisson3d_20_iCh": ichol0(poisson3d_matrix(20)),
        }
    elif name == "erdos_renyi":  # §6.2.4: p in {1e-4, 5e-4, 2e-3} at N=100k
        # keep expected row-degree: p' = p * (100_000 / N_SCALE)
        scale = 100_000 / N_SCALE
        mats = {
            f"ER_{N_SCALE//1000}k_p{p:g}": erdos_renyi_lower(
                N_SCALE, p * scale, seed=i
            )
            for i, p in enumerate((1e-4, 5e-4, 2e-3))
        }
    elif name == "narrow_band":  # §6.2.5: (p, B) pairs
        mats = {
            f"NB_p{p:g}_b{b:g}": narrow_band_lower(N_SCALE, p, b, seed=i)
            for i, (p, b) in enumerate(((0.14, 10), (0.05, 20), (0.03, 42)))
        }
    elif name == "corpus":  # autotuner scenario corpus (repro.autotune)
        from repro.autotune import corpus_entries

        mats = {e.name: e.matrix() for e in corpus_entries()}
    else:
        raise ValueError(name)
    return list(mats.items())


ALL_DATASETS = ("suitesparse", "ichol", "erdos_renyi", "narrow_band")


def time_callable(fn: Callable[[], object], *, reps: int = 5, warmup: int = 2) -> float:
    for _ in range(warmup):
        fn()
    times = []
    for _ in range(reps):
        t0 = time.perf_counter()
        fn()
        times.append(time.perf_counter() - t0)
    return float(np.median(times))


def solver_for(L, sched=None, width=None, *, strategy=None, k=K_CORES,
               cache=None, backend="scan", **plan_kw):
    """Bind an executor via the pipeline. Either pass a pre-built ``sched``
    (schedule-shootout drivers) or a registry ``strategy`` name; extra
    keywords flow to ``TriangularSolver.plan`` (e.g. ``reorder=False``)."""
    solver = TriangularSolver.plan(
        L, strategy=strategy or "growlocal", width=width, backend=backend,
        k=sched.k if sched is not None else k, cache=cache, sched=sched,
        **plan_kw,
    )
    rng = np.random.default_rng(0)
    b = rng.standard_normal(L.n_rows).astype(np.float32)
    import jax.numpy as jnp

    bj = jnp.asarray(b)
    solver.solve(bj).block_until_ready()  # compile
    return solver.solve, bj, solver.exec_plan


def geomean(xs: List[float]) -> float:
    xs = [x for x in xs if x > 0]
    return float(np.exp(np.mean(np.log(xs)))) if xs else float("nan")


def print_csv(name: str, rows: List[Tuple]):
    """Uniform output: name,us_per_call,derived."""
    for row in rows:
        print(",".join(str(r) for r in row), flush=True)


def rows_payload(csv_rows: List[Tuple], tables: List[str], **extra) -> dict:
    """The machine-readable twin of the CSV block: every benchmark row as
    a dict, plus run metadata. One schema shared by ``benchmarks.run
    --json`` and ``benchmarks.serve_load`` so downstream BENCH trajectory
    tooling parses a single format."""
    import time as _time

    return {
        "schema": "repro-bench-rows/v1",
        "generated_unix": round(_time.time(), 3),
        "tables": list(tables),
        "rows": [
            {"name": name, "us_per_call": us, "derived": derived}
            for name, us, derived in csv_rows
        ],
        **extra,
    }


def write_json_rows(
    path: str, csv_rows: List[Tuple], tables: List[str], **extra
) -> None:
    import json

    with open(path, "w") as fh:
        json.dump(rows_payload(csv_rows, tables, **extra), fh, indent=2)
        fh.write("\n")
    print(f"[json written to {path}]", flush=True)
