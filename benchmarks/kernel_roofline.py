"""Kernel-level roofline: the Pallas SpTRSV executor's arithmetic intensity
and the bytes it streams per solve — the §Roofline entry for the paper's own
workload (kernel view; the distributed view lives in launch/dryrun.py)."""
from __future__ import annotations

from benchmarks.common import (
    K_CORES,
    dag_from_lower_csr,
    dataset,
    schedule,
    solver_for,
    time_callable,
)
from repro.launch.roofline import HBM_BW, PEAK_FLOPS


def run(csv_rows):
    print("# Kernel roofline — Pallas SpTRSV plan traffic (TPU v5e model)")
    print(f"{'matrix':20s} {'flops':>12s} {'bytes':>12s} {'AI':>6s} "
          f"{'t_mem_us':>9s} {'t_comp_us':>9s} {'cpu_meas_us':>11s}")
    for mname, L in dataset("narrow_band") + dataset("erdos_renyi"):
        dag = dag_from_lower_csr(L)
        sched = schedule(dag, K_CORES, strategy="growlocal")
        solve, b, plan = solver_for(L, sched)
        stats = plan.stats()
        flops = 2.0 * (L.nnz - L.n_rows) + L.n_rows
        bytes_ = stats["bytes_streamed"] + 4 * L.n_rows * 3  # plan + b + x r/w
        ai = flops / bytes_
        t_mem = bytes_ / HBM_BW * 1e6
        t_comp = flops / PEAK_FLOPS * 1e6
        t_meas = time_callable(lambda: solve(b).block_until_ready()) * 1e6
        print(f"{mname:20s} {flops:12.3e} {bytes_:12.3e} {ai:6.3f} "
              f"{t_mem:9.2f} {t_comp:9.3f} {t_meas:11.1f}")
        csv_rows.append((f"roofline.{mname}.t_mem_us", round(t_mem, 2),
                         f"AI={ai:.3f};slot_util={stats['nnz_slot_utilization']:.3f}"))
