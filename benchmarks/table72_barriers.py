"""Paper Table 7.2 — reduction of synchronization barriers relative to the
number of wavefronts (geomean per data set). The paper's headline:
GrowLocal 14.99x on SuiteSparse vs HDagg 1.24x (12.07x relative)."""
from __future__ import annotations

from benchmarks.common import (
    ALL_DATASETS,
    K_CORES,
    SCHEDULERS,
    dag_from_lower_csr,
    dataset,
    geomean,
)
from repro.sparse import longest_path_length


def run(csv_rows):
    names = [n for n in SCHEDULERS if n != "Wavefront"]
    print("# Table 7.2 — geomean (#wavefronts / #supersteps)")
    print(f"{'dataset':14s} " + " ".join(f"{n:>11s}" for n in names))
    for ds in ALL_DATASETS:
        red = {n: [] for n in names}
        for mname, L in dataset(ds):
            dag = dag_from_lower_csr(L)
            wf = longest_path_length(dag)
            for sname in names:
                sched = SCHEDULERS[sname](dag, K_CORES)
                red[sname].append(wf / max(sched.n_supersteps, 1))
        cells = []
        for sname in names:
            gm = geomean(red[sname])
            cells.append(f"{gm:8.2f}")
            csv_rows.append((f"t72.{ds}.{sname}", round(gm, 2), ""))
        print(f"{ds:14s} " + " ".join(f"{c:>11s}" for c in cells))
