"""Paper Table 7.3 — impact of the §5 locality reordering: executor
wall-clock with and without the symmetric permutation (same schedule)."""
from __future__ import annotations

from benchmarks.common import (
    ALL_DATASETS,
    K_CORES,
    compile_plan,
    dag_from_lower_csr,
    dataset,
    geomean,
    grow_local,
    solver_for,
    time_callable,
)
from repro.solver import make_solver
import jax.numpy as jnp
import numpy as np


def run(csv_rows):
    print("# Table 7.3 — reordering ablation (speed-up of reordered vs not)")
    print(f"{'dataset':14s} {'reorder_gain':>12s}")
    for ds in ALL_DATASETS:
        gains = []
        for mname, L in dataset(ds):
            dag = dag_from_lower_csr(L)
            sched = grow_local(dag, K_CORES)
            # with reordering
            solve_r, b_r, _ = solver_for(L, sched)
            t_r = time_callable(lambda: solve_r(b_r).block_until_ready())
            # without reordering: compile the plan on the ORIGINAL ids
            plan = compile_plan(L, sched)
            solve_n = make_solver(plan)
            b = jnp.asarray(
                np.random.default_rng(0).standard_normal(L.n_rows), jnp.float32
            )
            solve_n(b).block_until_ready()
            t_n = time_callable(lambda: solve_n(b).block_until_ready())
            gains.append(t_n / t_r)
        g = geomean(gains)
        print(f"{ds:14s} {g:12.3f}")
        csv_rows.append((f"t74.{ds}.reorder_gain", round(g, 3), ""))
