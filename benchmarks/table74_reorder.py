"""Paper Table 7.3 — impact of the §5 locality reordering: executor
wall-clock with and without the symmetric permutation (same strategy,
toggled through the pipeline's ``reorder`` option)."""
from __future__ import annotations

from benchmarks.common import (
    ALL_DATASETS,
    K_CORES,
    dataset,
    geomean,
    solver_for,
    time_callable,
)


def run(csv_rows):
    print("# Table 7.3 — reordering ablation (speed-up of reordered vs not)")
    print(f"{'dataset':14s} {'reorder_gain':>12s}")
    for ds in ALL_DATASETS:
        gains = []
        for mname, L in dataset(ds):
            # with reordering (pipeline default)
            solve_r, b_r, _ = solver_for(L, strategy="growlocal", k=K_CORES)
            t_r = time_callable(lambda: solve_r(b_r).block_until_ready())
            # without: the plan compiles on the ORIGINAL ids
            solve_n, b_n, _ = solver_for(
                L, strategy="growlocal", k=K_CORES, reorder=False
            )
            t_n = time_callable(lambda: solve_n(b_n).block_until_ready())
            gains.append(t_n / t_r)
        g = geomean(gains)
        print(f"{ds:14s} {g:12.3f}")
        csv_rows.append((f"t74.{ds}.reorder_gain", round(g, 3), ""))
