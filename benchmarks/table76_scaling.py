"""Paper Table 7.5/Fig 7.2 — scaling with the number of cores k (modeled
BSP speed-up; the schedule quality trend with k is the scheduler property)."""
from __future__ import annotations

from benchmarks.common import (
    K_CORES,
    bsp_cost,
    dag_from_lower_csr,
    dataset,
    geomean,
    schedule,
    serial_schedule,
)
from repro.sparse import average_wavefront_size

CORES = (4, 8, 16, 32, 64)


def run(csv_rows):
    print("# Table 7.5 — GrowLocal modeled speed-up vs cores (suitesparse-sub)")
    print(f"{'matrix':162s}"[:20] + " avg_wf " + " ".join(f"k={k:<5d}" for k in CORES))
    rows = {k: [] for k in CORES}
    for mname, L in dataset("suitesparse") + dataset("narrow_band"):
        dag = dag_from_lower_csr(L)
        ser = bsp_cost(dag, serial_schedule(dag))
        cells = []
        for k in CORES:
            s = schedule(dag, k, strategy="growlocal")
            sp = ser / bsp_cost(dag, s)
            rows[k].append(sp)
            cells.append(f"{sp:6.2f}")
        print(f"{mname:20s} {average_wavefront_size(dag):6.0f} " + " ".join(cells))
    for k in CORES:
        csv_rows.append((f"t76.k{k}.geomean_speedup", round(geomean(rows[k]), 3), ""))
    print("geomean             " + "       " + " ".join(
        f"{geomean(rows[k]):6.2f}" for k in CORES))

    # the second scaling axis: row-sharding one schedule across devices
    # (core.rowshard, host-only here) — halo traffic vs the all-gather
    # baseline at 4 shards, on the same corpus
    from repro.core import apply_reordering, compile_plan, partition_plan
    from repro.pipeline import schedule as _sched

    print("\n# row partition at 4 shards — halo_ratio "
          "(halo values / all_gather values per solve)")
    ratios = []
    for mname, L in dataset("suitesparse") + dataset("narrow_band"):
        dag = dag_from_lower_csr(L)
        s = _sched(dag, K_CORES, strategy="growlocal")
        L2, s2, _, _ = apply_reordering(L, s)
        rsp = partition_plan(compile_plan(L2, s2), 4)
        r = rsp.comm_stats()["halo_ratio"]
        ratios.append(r)
        print(f"{mname:20s} halo_ratio {r:8.4f}")
    csv_rows.append(
        ("t76.rows4.halo_ratio", round(geomean(ratios), 5), "geomean")
    )
