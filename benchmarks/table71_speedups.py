"""Paper Table 7.1 — geometric-mean speed-up over Serial per data set.

Speed-up here has two readings, both reported:
  * measured — wall-clock of the JAX scan executor with each scheduler's
    plan vs the serial plan (CPU container; one chip's vector units stand in
    for the 22-core CPU);
  * modeled  — BSP cost model ratio (work + L·barriers), the quantity the
    schedulers optimize (paper §2.2).
"""
from __future__ import annotations

from benchmarks.common import (
    ALL_DATASETS,
    K_CORES,
    SCHEDULERS,
    bsp_cost,
    dag_from_lower_csr,
    dataset,
    geomean,
    serial_schedule,
    solver_for,
    time_callable,
)


def run(csv_rows):
    header = f"{'dataset':14s} " + " ".join(f"{n:>11s}" for n in SCHEDULERS)
    print("# Table 7.1 — geomean speed-up over Serial (measured | modeled)")
    print(header)
    for ds in ALL_DATASETS:
        meas = {n: [] for n in SCHEDULERS}
        mod = {n: [] for n in SCHEDULERS}
        for mname, L in dataset(ds):
            dag = dag_from_lower_csr(L)
            ser = serial_schedule(dag)
            ser_cost = bsp_cost(dag, ser)
            solve_s, b_s, _ = solver_for(L, ser)
            t_serial = time_callable(lambda: solve_s(b_s).block_until_ready())
            for sname, fn in SCHEDULERS.items():
                sched = fn(dag, K_CORES)
                solve, b, _ = solver_for(L, sched)
                t = time_callable(lambda: solve(b).block_until_ready())
                meas[sname].append(t_serial / t)
                mod[sname].append(ser_cost / bsp_cost(dag, sched))
        cells = []
        for sname in SCHEDULERS:
            gm, gmod = geomean(meas[sname]), geomean(mod[sname])
            cells.append(f"{gm:5.2f}|{gmod:5.2f}")
            csv_rows.append(
                (f"t71.{ds}.{sname}", round(gm, 3), round(gmod, 3))
            )
        print(f"{ds:14s} " + " ".join(f"{c:>11s}" for c in cells))
