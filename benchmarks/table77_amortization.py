"""Paper Table 7.6 — amortization threshold:
scheduling_time / (serial_exec - parallel_exec); how many solves pay for
the inspector (quartiles per scheduler).

Single-core container note: the parallel execution time is MODELED as
serial_exec * (BSP parallel cost / BSP serial cost) — on one physical core a
parallel schedule can never beat serial wall-clock, which would make the
paper's metric degenerate (+inf); the BSP model is the quantity the paper's
schedulers optimize."""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import (
    ALL_DATASETS,
    K_CORES,
    SCHEDULERS,
    bsp_cost,
    dag_from_lower_csr,
    dataset,
    serial_schedule,
    solver_for,
    time_callable,
)


def run(csv_rows):
    print("# Table 7.6 — amortization threshold (Q25 / median / Q75)")
    print("# parallel exec time modeled via BSP cost (see module docstring)")
    print(f"{'scheduler':12s} {'Q25':>9s} {'median':>9s} {'Q75':>9s}")
    mats = [mt for ds in ALL_DATASETS for mt in dataset(ds)]
    for sname, fn in SCHEDULERS.items():
        ratios = []
        for mname, L in mats:
            dag = dag_from_lower_csr(L)
            t0 = time.perf_counter()
            sched = fn(dag, K_CORES)
            t_sched = time.perf_counter() - t0
            ser = serial_schedule(dag)
            solve_s, b_s, _ = solver_for(L, ser)
            t_serial = time_callable(lambda: solve_s(b_s).block_until_ready(),
                                     reps=3)
            t_par = t_serial * bsp_cost(dag, sched) / bsp_cost(dag, ser)
            if t_serial > t_par:
                ratios.append(t_sched / (t_serial - t_par))
            else:
                ratios.append(float("inf"))
        finite = [r for r in ratios if np.isfinite(r)]
        if not finite:
            print(f"{sname:12s} {'inf':>9s} {'inf':>9s} {'inf':>9s}")
            csv_rows.append((f"t77.{sname}.median_amortization", "inf", ""))
            continue
        q25, med, q75 = np.percentile(finite, [25, 50, 75])
        n_inf = len(ratios) - len(finite)
        print(f"{sname:12s} {q25:9.1f} {med:9.1f} {q75:9.1f}"
              + (f"   ({n_inf} no-gain matrices excluded)" if n_inf else ""))
        csv_rows.append((f"t77.{sname}.median_amortization", round(float(med), 2),
                         f"q25={q25:.1f};q75={q75:.1f};excluded={n_inf}"))
