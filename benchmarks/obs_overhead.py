"""Observability overhead — the ``repro.obs`` acceptance bench.

Tracing must be free when off and cheap when on. This bench enforces
both on the corpus hot path (warm plans, compiled executors — the
steady-state serving regime where per-solve overhead matters):

  * **disabled** (bar: <= 0.5%): the off path of every instrumentation
    site is one module-flag check returning a shared null span. The
    per-call cost is microbenchmarked directly, multiplied by the number
    of sites one warm solve actually crosses (counted from a traced
    solve), and compared against the measured solve latency.
  * **enabled** (bar: <= 3% median): per-sample interleaved A/B — every
    iteration times one solve with tracing off then one with tracing on,
    so host-load drift lands on both arms identically (block-wise A/B on
    a shared host showed +-10% drift between blocks, dwarfing the real
    ~2us/solve span cost). The overhead is the ratio of the two arm
    medians, minimum over ``rounds`` repeats; aggregate acceptance is
    the geomean across corpus matrices.
  * **round-trip**: one ``plan(strategy="auto", cache=..., timed=True)``
    + solve traced end-to-end, exported as Chrome trace JSON, re-parsed
    and structurally validated (monotonic ts, matched B/E pairs), and
    required to contain spans from >= 4 layers (inspector, autotune,
    cache, backend, executor).

  PYTHONPATH=src:. python -m benchmarks.obs_overhead
  PYTHONPATH=src:. python -m benchmarks.obs_overhead --smoke --json o.json
"""
from __future__ import annotations

import argparse
import os
import sys
import tempfile
import time

import numpy as np

from benchmarks.common import dataset, geomean, solver_for, write_json_rows
from repro import obs
from repro.pipeline import PlanCache, TriangularSolver

DISABLED_BAR = 0.005  # off-path instrumentation cost / solve latency
ENABLED_BAR = 0.03  # traced solve latency / untraced solve latency - 1
MIN_LAYERS = 4  # distinct span cats required in the round-trip trace

LAYERS = ("inspector", "autotune", "cache", "backend", "executor")


def _paired_medians_us(fn, b, reps: int, buf) -> tuple:
    """(median_off_us, median_on_us) from per-sample interleaved timing:
    each rep times one untraced solve then one traced solve, so slow
    phases of a shared host inflate both arms alike."""
    off, on = [], []
    for _ in range(reps):
        obs.disable()
        t0 = time.perf_counter_ns()
        fn(b).block_until_ready()
        off.append(time.perf_counter_ns() - t0)
        obs.enable(buf)
        t0 = time.perf_counter_ns()
        fn(b).block_until_ready()
        on.append(time.perf_counter_ns() - t0)
    obs.disable()
    return float(np.median(off)) / 1e3, float(np.median(on)) / 1e3


def measure_null_site_ns(iters: int = 200_000) -> float:
    """ns per disabled instrumentation site (span enter/exit + one
    ``set`` + a counter bump — a deliberately pessimistic site)."""
    assert not obs.is_enabled()
    t0 = time.perf_counter_ns()
    for _ in range(iters):
        with obs.span("obs_overhead.probe", cat="executor") as sp:
            sp.set(probe=True)
        obs.counter_add("obs_overhead.probe")
    return (time.perf_counter_ns() - t0) / iters


def count_sites_per_solve(fn, b) -> int:
    """Instrumentation sites one warm solve crosses, counted by tracing
    a single solve into a fresh buffer."""
    buf = obs.TraceBuffer("obs_overhead.count")
    with obs.tracing(buf):
        fn(b).block_until_ready()
    # counters() values are increments here (fresh buffer)
    return len(buf) + sum(buf.counters().values())


def measure_matrix(name, L, *, rounds: int, reps: int, cache) -> dict:
    fn, b, _ = solver_for(L, strategy="growlocal", cache=cache)
    buf = obs.TraceBuffer(f"obs_overhead.{name}")
    overheads, offs, ons = [], [], []
    for _ in range(rounds):
        buf.clear()
        o_off, o_on = _paired_medians_us(fn, b, reps, buf)
        offs.append(o_off)
        ons.append(o_on)
        overheads.append(o_on / o_off - 1.0)
    # each round is internally drift-immune (paired sampling); the
    # median across rounds drops rounds a scheduler hiccup still skewed
    # without biasing the estimate toward either arm
    overhead = float(np.median(overheads))
    off, on = float(np.median(offs)), float(np.median(ons))
    n_sites = count_sites_per_solve(fn, b)
    site_ns = measure_null_site_ns()
    return {
        "matrix": name,
        "n": L.n_rows,
        "solve_us_off": round(off, 2),
        "solve_us_on": round(on, 2),
        "enabled_overhead": overhead,
        "sites_per_solve": n_sites,
        "null_site_ns": round(site_ns, 1),
        "disabled_overhead": (n_sites * site_ns) / (off * 1e3),
    }


def roundtrip_trace(L, trace_path: str) -> dict:
    """Trace one cold ``plan()`` + timed solve end-to-end, export, and
    re-parse — the cross-layer acceptance artifact."""
    rng = np.random.default_rng(0)
    b = rng.standard_normal(L.n_rows).astype(np.float32)
    buf = obs.TraceBuffer("obs_overhead.roundtrip")
    with obs.tracing(buf):
        solver = TriangularSolver.plan(
            L, strategy="auto", cache=PlanCache(), timed=True
        )
        x, _steps = solver.solve_timed(b)
    # correctness spot-check so the artifact is a real solve, not a stub
    from repro.sparse.csr import csr_to_dense

    r = csr_to_dense(L) @ np.asarray(x, np.float64) - b
    assert float(np.max(np.abs(r))) < 1e-3 * max(1.0, float(np.abs(b).max()))
    payload = obs.export_chrome_trace(trace_path, buf)
    reparsed = obs.load_chrome_trace(trace_path)
    report = obs.validate_chrome_trace(reparsed)
    assert payload["schema"] == obs.TRACE_SCHEMA
    layers = [c for c in report["cats"] if c in LAYERS]
    if len(layers) < MIN_LAYERS:
        raise SystemExit(
            f"round-trip trace spans only layers {layers} "
            f"(need >= {MIN_LAYERS} of {list(LAYERS)})"
        )
    return {**report, "layers": layers, "trace": trace_path}


def run(csv_rows, *, smoke: bool = False, trace_path: str = None) -> dict:
    mats = dataset("corpus")
    rounds, reps = (5, 20) if smoke else (7, 50)
    if smoke:
        mats = mats[:2]
    cache = PlanCache()
    print(
        f"# obs_overhead — corpus hot path, {len(mats)} matrices, "
        f"{rounds} rounds x {reps} paired off/on reps "
        f"(median of round overheads)"
    )
    print(
        f"{'matrix':22s} {'off us':>9s} {'on us':>9s} {'on +%':>7s} "
        f"{'sites':>6s} {'site ns':>8s} {'off +%':>8s}"
    )
    per = []
    for name, L in mats:
        m = measure_matrix(name, L, rounds=rounds, reps=reps, cache=cache)
        per.append(m)
        print(
            f"{m['matrix']:22s} {m['solve_us_off']:9.1f} "
            f"{m['solve_us_on']:9.1f} {100 * m['enabled_overhead']:7.2f} "
            f"{m['sites_per_solve']:6d} {m['null_site_ns']:8.1f} "
            f"{100 * m['disabled_overhead']:8.4f}"
        )
        csv_rows.append(
            (
                f"obs_overhead.{m['matrix']}",
                m["solve_us_on"],
                round(m["enabled_overhead"], 5),
            )
        )
    # aggregate bars: geomean of (1 + overhead) across the corpus — one
    # noisy matrix cannot mask a systemic regression, nor sink the run
    enabled = geomean([1.0 + m["enabled_overhead"] for m in per]) - 1.0
    disabled = max(m["disabled_overhead"] for m in per)
    print(
        f"enabled overhead geomean {100 * enabled:.2f}% "
        f"(bar <= {100 * ENABLED_BAR:g}%), disabled worst-case "
        f"{100 * disabled:.4f}% (bar <= {100 * DISABLED_BAR:g}%)"
    )
    ok = True
    if disabled > DISABLED_BAR:
        ok = False
        print(f"MISS: disabled-path overhead {100 * disabled:.4f}%")
    if enabled > ENABLED_BAR:
        ok = False
        print(f"MISS: enabled overhead {100 * enabled:.2f}%")

    if trace_path is None:
        trace_path = os.path.join(
            tempfile.mkdtemp(prefix="obs_overhead."), "roundtrip.json"
        )
    rt = roundtrip_trace(mats[0][1], trace_path)
    print(
        f"round-trip trace: {rt['n_events']} events, {rt['n_pairs']} "
        f"span pairs, layers={rt['layers']} -> {rt['trace']}"
    )
    csv_rows.append(
        ("obs_overhead.roundtrip.pairs", float(rt["n_pairs"]),
         "+".join(rt["layers"]))
    )
    if not ok:
        raise SystemExit("obs_overhead: acceptance bars MISSED")
    print("obs_overhead acceptance: PASS")
    return {"per_matrix": per, "enabled_geomean": enabled,
            "disabled_worst": disabled, "roundtrip": rt, "accepted": ok}


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument(
        "--smoke", action="store_true",
        help="short CI run: 2 corpus matrices, fewer rounds",
    )
    ap.add_argument("--json", metavar="PATH", default=None)
    ap.add_argument(
        "--trace", metavar="PATH", default=None,
        help="where to write the round-trip Chrome trace "
             "(default: a temp dir)",
    )
    args = ap.parse_args(argv)
    csv_rows = []
    out = run(csv_rows, smoke=args.smoke, trace_path=args.trace)
    print("\n# CSV: name,us_per_call,derived")
    for name, val, derived in csv_rows:
        print(f"{name},{val},{derived}")
    if args.json:
        write_json_rows(args.json, csv_rows, ["obs_overhead"], obs=out)


if __name__ == "__main__":
    main(sys.argv[1:])
