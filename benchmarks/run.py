"""Benchmark harness — one module per paper table. Prints human tables to
stdout and a ``name,us_per_call,derived`` CSV block at the end; with
``--json PATH`` the same rows are written as machine-readable JSON
(schema ``repro-bench-rows/v1``, shared with ``benchmarks.serve_load``)
to seed the BENCH trajectory.

  PYTHONPATH=src python -m benchmarks.run                   # all tables
  PYTHONPATH=src python -m benchmarks.run t71 t72           # subset
  PYTHONPATH=src python -m benchmarks.run t7x --json out.json
  PYTHONPATH=src python -m benchmarks.run t71 --trace trace.json

``--trace PATH`` runs the selected tables under ``repro.obs`` tracing
and writes a Chrome ``trace_event`` file (open in Perfetto / chrome
about:tracing) plus the per-span aggregate as ``obs.*`` CSV rows.
"""
from __future__ import annotations

import argparse
import time

TABLES = {
    "t71": ("table71_speedups", "Table 7.1 speed-ups over Serial"),
    "t72": ("table72_barriers", "Table 7.2 barrier reduction"),
    "t73": ("table73_funnel", "§7.3 Funnel coarsening ablation"),
    "t74": ("table74_reorder", "Table 7.3 reordering ablation"),
    "t75": ("table75_arch", "Table 7.4 executors/architectures"),
    "t76": ("table76_scaling", "Table 7.5 core scaling"),
    "t77": ("table77_amortization", "Table 7.6 amortization threshold"),
    "t78": ("table78_blocks", "Table 7.7 block-parallel scheduling"),
    "t7x": ("table7x_auto", "Auto-strategy vs best/worst fixed (corpus)"),
    "roofline": ("kernel_roofline", "Kernel roofline"),
}


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument(
        "tables", nargs="*",
        help=f"table keys to run (default: all of {', '.join(TABLES)})",
    )
    ap.add_argument(
        "--json", metavar="PATH", default=None,
        help="also write every row as machine-readable JSON to PATH",
    )
    ap.add_argument(
        "--trace", metavar="PATH", default=None,
        help="trace the run with repro.obs and write a Chrome "
             "trace_event JSON to PATH (spans also appear as obs.* rows)",
    )
    args = ap.parse_args()
    unknown = [t for t in args.tables if t not in TABLES]
    if unknown:
        ap.error(f"unknown tables {unknown}; available: {list(TABLES)}")
    which = args.tables or list(TABLES)
    trace_buf = None
    if args.trace:
        from repro import obs

        trace_buf = obs.enable()
    csv_rows = []
    for key in which:
        mod_name, desc = TABLES[key]
        print(f"\n===== {key}: {desc} =====", flush=True)
        t0 = time.time()
        mod = __import__(f"benchmarks.{mod_name}", fromlist=["run"])
        mod.run(csv_rows)
        print(f"[{key} done in {time.time()-t0:.1f}s]", flush=True)
    if trace_buf is not None:
        from repro import obs

        obs.disable()
        obs.export_chrome_trace(args.trace, trace_buf)
        csv_rows.extend(obs.metrics_rows(trace_buf))
        print(f"\n[trace: {len(trace_buf)} spans -> {args.trace}]")
    print("\n# CSV: name,us_per_call,derived")
    for name, val, derived in csv_rows:
        print(f"{name},{val},{derived}")
    if args.json:
        from benchmarks.common import write_json_rows

        write_json_rows(args.json, csv_rows, which)


if __name__ == "__main__":
    main()
