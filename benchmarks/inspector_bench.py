"""Inspector-phase benchmark — plan compile + numeric update vs N.

The paper's whole value proposition is a cheap inspector amortized over
many executes (§7.7); this driver measures the two inspector-side hot
paths the vectorized stack optimizes, on corpus families scaled to
N in {1e4, 1e5}:

  * **compile** — the vectorized ``compile_plan`` (O(nnz) array passes)
    against ``_reference_compile_plan`` (the original per-row Python
    compiler, kept as the equivalence oracle). Every timed pair is also
    checked *bitwise* (``plans_bitwise_equal``) — a fast-but-different
    plan would be worthless.
  * **numeric update** — the ``repro.backends`` device-side
    ``BoundSolve.update_values`` (an O(nnz) gather through
    ``val_src``/``diag_src``; only the new entry data crosses to the
    device) against the old full rebind (retransfer of every [T, k, W]
    plan tensor), on the scan backend.
  * **entry permutation** — the scatter/lexsort
    ``pipeline.solver._entry_permutation`` (rebases ``val_src`` onto the
    caller's entry order; runs once per plan) against the old float64
    carrier-matrix path through ``permute_symmetric``, at N=1e6
    (``--smoke``: N=1e4), checked element-for-element.

Acceptance (ISSUE 4): vectorized compile >= 10x the reference at N=1e5.

Output: human table + ``repro-bench-rows/v1`` JSON (``--json``), the
same schema as ``benchmarks.run --json`` / ``benchmarks.serve_load``.

  PYTHONPATH=src:. python -m benchmarks.inspector_bench --json insp.json
  PYTHONPATH=src:. python -m benchmarks.inspector_bench --smoke  # CI:
      N=1e4 rows only + the bitwise equivalence assert
"""
from __future__ import annotations

import argparse
import sys
import time

import numpy as np

from benchmarks.common import write_json_rows
from repro.autotune import scale_corpus_entry
from repro.backends import get_backend
from repro.core.plan import (
    _reference_compile_plan,
    compile_plan,
    plans_bitwise_equal,
)
from repro.pipeline import schedule
from repro.sparse import (
    CSRMatrix,
    dag_from_lower_csr,
    erdos_renyi_lower,
    narrow_band_lower,
    permute_symmetric,
)

K = 8
ACCEPT_SPEEDUP = 10.0  # at N=1e5

# family -> N -> matrix factory. The 1e5 points ARE the autotune scale
# tier's entries (one ground truth — the same matrices the selector's
# scale-stability test validates); the 1e4 points use the same family
# parameters at the intermediate size.
FAMILIES = {
    "er_sparse": {
        10_000: lambda: erdos_renyi_lower(10_000, 0.002 * 800 / 10_000,
                                          seed=201),
        100_000: scale_corpus_entry("er_sparse_100k").make,
    },
    "band_narrow": {
        10_000: lambda: narrow_band_lower(10_000, 0.14, 10, seed=203),
        100_000: scale_corpus_entry("band_narrow_100k").make,
    },
}


def _median_time(fn, reps: int) -> float:
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        fn()
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def _bench_point(name: str, L, *, reps_vec: int, reps_ref: int) -> dict:
    import jax

    dag = dag_from_lower_csr(L)
    t0 = time.perf_counter()
    s = schedule(dag, K, strategy="growlocal")
    t_sched = time.perf_counter() - t0

    plan = compile_plan(L, s)
    ref = _reference_compile_plan(L, s)
    equal = plans_bitwise_equal(plan, ref)
    t_vec = _median_time(lambda: compile_plan(L, s), reps_vec)
    t_ref = _median_time(lambda: _reference_compile_plan(L, s), reps_ref)

    # numeric update: device-side gather refresh vs full-tensor rebind.
    # block_until_ready on the refreshed tensors so async dispatch does
    # not flatter the gather path.
    backend = get_backend("scan")
    bound = backend.bind(plan, dtype=np.float32)
    rng = np.random.default_rng(0)
    new_data = L.data * rng.uniform(0.5, 2.0, L.nnz)

    def device_update():
        b2 = bound.update_values(new_data)
        jax.block_until_ready((b2._pa.vals, b2._pa.diag))

    def full_rebind():
        plan.numeric_update(new_data)  # the old path mutated the host plan
        b2 = backend.bind(plan, dtype=np.float32)  # ...then retransferred
        jax.block_until_ready((b2._pa.vals, b2._pa.diag))

    device_update()  # warm-up: jit the gather kernel for this plan shape
    full_rebind()
    t_upd = _median_time(device_update, max(reps_vec, 3))
    t_rebind = _median_time(full_rebind, max(reps_ref, 2))

    return {
        "name": name,
        "n": L.n_rows,
        "nnz": L.nnz,
        "n_supersteps": s.n_supersteps,
        "schedule_seconds": round(t_sched, 4),
        "compile_vec_seconds": t_vec,
        "compile_ref_seconds": t_ref,
        "compile_speedup": t_ref / t_vec,
        "bitwise_equal": bool(equal),
        "update_device_seconds": t_upd,
        "update_rebind_seconds": t_rebind,
        "update_speedup": t_rebind / t_upd,
    }


def _bench_entry_perm(csv_rows, *, smoke: bool) -> dict:
    """Time ``_entry_permutation`` (scatter + lexsort) against the old
    float64-carrier path it replaced, on a banded pattern at N=1e6."""
    from repro.pipeline.solver import _entry_permutation

    n = 10_000 if smoke else 1_000_000
    L = narrow_band_lower(n, 0.14, 10, seed=207)
    perm = np.random.default_rng(0).permutation(n)

    def carrier_ref():
        # the pre-vectorization implementation: ride entry ids through
        # permute_symmetric on a float64 carrier (ids exact below 2^53)
        carrier = CSRMatrix(
            n_rows=L.n_rows, n_cols=L.n_cols, indptr=L.indptr,
            indices=L.indices, data=np.arange(L.nnz, dtype=np.float64),
        )
        return permute_symmetric(carrier, perm).data.astype(np.int64)

    equal = bool(np.array_equal(_entry_permutation(L, perm), carrier_ref()))
    reps = 5 if smoke else 3
    t_vec = _median_time(lambda: _entry_permutation(L, perm), reps)
    t_ref = _median_time(carrier_ref, reps)
    tag = f"entry_perm.{n // 1000}k"
    print(
        f"{tag:22s} {L.nnz:9d} {t_vec*1e3:9.1f} {t_ref*1e3:10.1f} "
        f"{t_ref/t_vec:7.1f}x {str(equal):>6s}"
    )
    csv_rows.append(
        (f"inspector.{tag}.vec", round(t_vec * 1e6, 1),
         round(t_ref / t_vec, 2))
    )
    csv_rows.append((f"inspector.{tag}.ref", round(t_ref * 1e6, 1), 1.0))
    return {
        "name": tag,
        "n": n,
        "nnz": L.nnz,
        "vec_seconds": t_vec,
        "ref_seconds": t_ref,
        "speedup": t_ref / t_vec,
        "bitwise_equal": equal,
    }


def run(csv_rows, *, smoke: bool = False) -> dict:
    sizes = (10_000,) if smoke else (10_000, 100_000)
    print(
        f"# inspector_bench — vectorized compile_plan + device numeric "
        f"update, k={K}, growlocal ({'smoke: N=1e4 only' if smoke else 'full'})"
    )
    print(
        f"{'matrix':22s} {'nnz':>9s} {'vec ms':>9s} {'ref ms':>10s} "
        f"{'speedup':>8s} {'equal':>6s} {'upd us':>9s} {'rebind us':>10s} "
        f"{'upd spd':>8s}"
    )
    out = {}
    all_equal = True
    speedup_1e5 = []
    for fam, points in FAMILIES.items():
        for n in sizes:
            L = points[n]()
            tag = f"{fam}.{n // 1000}k"
            r = _bench_point(
                tag, L,
                reps_vec=5 if n <= 10_000 else 3,
                reps_ref=2 if n <= 10_000 else 1,
            )
            out[tag] = r
            all_equal &= r["bitwise_equal"]
            if n >= 100_000:
                speedup_1e5.append(r["compile_speedup"])
            print(
                f"{tag:22s} {r['nnz']:9d} {r['compile_vec_seconds']*1e3:9.1f} "
                f"{r['compile_ref_seconds']*1e3:10.1f} "
                f"{r['compile_speedup']:7.1f}x {str(r['bitwise_equal']):>6s} "
                f"{r['update_device_seconds']*1e6:9.1f} "
                f"{r['update_rebind_seconds']*1e6:10.1f} "
                f"{r['update_speedup']:7.1f}x"
            )
            csv_rows.append(
                (f"inspector.{tag}.compile_vec",
                 round(r["compile_vec_seconds"] * 1e6, 1),
                 round(r["compile_speedup"], 2))
            )
            csv_rows.append(
                (f"inspector.{tag}.compile_ref",
                 round(r["compile_ref_seconds"] * 1e6, 1), 1.0)
            )
            csv_rows.append(
                (f"inspector.{tag}.update_device",
                 round(r["update_device_seconds"] * 1e6, 1),
                 round(r["update_speedup"], 2))
            )
            csv_rows.append(
                (f"inspector.{tag}.update_rebind",
                 round(r["update_rebind_seconds"] * 1e6, 1), 1.0)
            )
    ep = _bench_entry_perm(csv_rows, smoke=smoke)
    out[ep["name"]] = ep
    all_equal &= ep["bitwise_equal"]
    if not all_equal:
        raise SystemExit(
            "inspector_bench FAILED: vectorized plan is not bitwise-equal "
            "to the reference compiler"
        )
    print("bitwise equivalence (vectorized vs reference): PASS")
    if speedup_1e5:
        worst = min(speedup_1e5)
        ok = worst >= ACCEPT_SPEEDUP
        print(
            f"N=1e5 acceptance (>= {ACCEPT_SPEEDUP:.0f}x compile speedup): "
            f"{'PASS' if ok else 'MISS'} (worst {worst:.1f}x)"
        )
        out["accept_10x_at_1e5"] = bool(ok)
    return out


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--json", metavar="PATH", default=None)
    ap.add_argument(
        "--smoke", action="store_true",
        help="short CI run: N=1e4 rows only; still asserts bitwise "
        "equivalence (exits non-zero on mismatch)",
    )
    args = ap.parse_args(argv)
    csv_rows = []
    out = run(csv_rows, smoke=args.smoke)
    print("\n# CSV: name,us_per_call,derived")
    for name, val, derived in csv_rows:
        print(f"{name},{val},{derived}")
    if args.json:
        write_json_rows(args.json, csv_rows, ["inspector"], inspector=out)


if __name__ == "__main__":
    main(sys.argv[1:])
