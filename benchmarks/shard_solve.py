"""Sharded single-solve — halo exchange vs all-gather comm + wall clock.

Compares the two distributed executors on one multi-device CPU mesh:

  * **model** — the k-wide model-axis shard (``shard="model"``): every
    superstep broadcasts ALL x-fragments with a full ``all_gather``
    (O(k * T) values per device per solve);
  * **rows**  — the row partition (``shard="rows"``): per-shard resident
    x, one static halo exchange per superstep moving only the boundary
    values (``core.rowshard``).

Per matrix it reports wall clock for both, the comm volumes from the
partition's static model AND from live ``obs`` counters
(``rowshard.halo_values`` / ``rowshard.halo_bytes``, bumped per solve by
the bound), and the headline ``halo_ratio`` = halo traffic / all-gather
baseline. ``--smoke`` additionally asserts the sharded solve is bitwise
equal to the single-chip scan solve and that ``halo_ratio <= 0.25`` on
the banded instance (the acceptance bound; locality matrices are the
regime the §5 reorder makes contiguous). The full run includes an
N >= 1e6 narrow-band partitioned solve whose plan exceeds any single
shard's share — the scale the row partition exists for.

Output: human table + ``repro-bench-rows/v1`` JSON (``--json``), same
schema as ``benchmarks.run --json``.

  PYTHONPATH=src:. python -m benchmarks.shard_solve --smoke --json rows.json
  PYTHONPATH=src:. python -m benchmarks.shard_solve --n 1000000
"""
from __future__ import annotations

import os

# must precede the first jax import: jax locks the host device count at
# first init (same isolation launch/dryrun.py uses)
os.environ.setdefault(
    "XLA_FLAGS", "--xla_force_host_platform_device_count=8"
)

import argparse
import sys
import time

import numpy as np


def _mats(smoke: bool, n_big: int):
    from repro.sparse.generators import erdos_renyi_lower, narrow_band_lower

    if smoke:
        return [
            ("band_20k", narrow_band_lower(20_000, 0.12, 8, seed=2)),
            ("er_10k", erdos_renyi_lower(10_000, 2e-4, seed=9)),
        ]
    return [
        ("band_200k", narrow_band_lower(200_000, 0.12, 8, seed=2)),
        ("er_100k", erdos_renyi_lower(100_000, 2e-5, seed=9)),
        (f"band_{n_big // 1000}k", narrow_band_lower(n_big, 0.12, 8, seed=3)),
    ]


def _timeit(fn, reps: int) -> float:
    fn()  # warm (compile)
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        fn()
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def run(args) -> int:
    import jax

    from benchmarks.common import write_json_rows
    from repro import obs
    from repro.pipeline import PlanCache, TriangularSolver

    n_dev = len(jax.devices())
    mesh = jax.make_mesh((1, n_dev), ("data", "model"))
    cache = PlanCache()
    csv_rows = []
    reps = 3 if args.smoke else 5
    print(f"# shard_solve — rows (halo) vs model (all_gather), "
          f"{n_dev}-device CPU mesh")
    print(f"{'matrix':12s} {'n':>9s} {'model_us':>10s} {'rows_us':>10s} "
          f"{'halo_ratio':>10s} {'halo_KiB':>9s} {'ag_KiB':>9s}")

    ok = True
    for name, L in _mats(args.smoke, args.n):
        n = L.n_rows
        b = np.random.default_rng(7).standard_normal(n).astype(np.float32)

        rows = TriangularSolver.plan(
            L, k=8, backend="distributed", mesh=mesh, shard="rows",
            cache=cache,
        )
        ex = rows.bound.describe()["exchange"]

        # live counters: one solve under tracing, report what the bound
        # actually recorded (the acceptance wants measured, not modeled).
        # A fresh buffer per matrix — the default buffer accumulates.
        with obs.tracing(obs.TraceBuffer(f"rows.{name}")) as buf:
            x_rows = np.asarray(rows.solve(b))
        counters = buf.counters()
        halo_vals = counters.get("rowshard.halo_values", 0)
        halo_bytes = counters.get("rowshard.halo_bytes", 0)
        assert halo_vals == ex["halo_values_per_solve"], (
            halo_vals, ex["halo_values_per_solve"])

        t_rows = _timeit(lambda: rows.solve(b), reps)

        # the model-axis baseline broadcasts everything; at bench scale
        # its per-solve all_gather volume comes straight from the model
        t_model = float("nan")
        if n <= args.model_cap:
            model = TriangularSolver.plan(
                L, k=8, backend="distributed", mesh=mesh, shard="model",
                cache=cache,
            )
            t_model = _timeit(lambda: model.solve(b), reps)

        ratio = ex["halo_ratio"]
        print(f"{name:12s} {n:9d} {t_model * 1e6:10.0f} "
              f"{t_rows * 1e6:10.0f} {ratio:10.4f} "
              f"{halo_bytes / 1024:9.1f} {ex['allgather_bytes'] / 1024:9.1f}")
        csv_rows += [
            (f"rows.{name}.us_per_solve", round(t_rows * 1e6, 1), ""),
            (f"rows.{name}.halo_ratio", round(ratio, 5), ""),
            (f"rows.{name}.halo_bytes", halo_bytes, "obs counter"),
            (f"rows.{name}.allgather_bytes", ex["allgather_bytes"], ""),
            (f"rows.{name}.exchange_rounds", ex["rounds"], ""),
        ]
        if not np.isnan(t_model):
            csv_rows.append(
                (f"model.{name}.us_per_solve", round(t_model * 1e6, 1), "")
            )

        if args.smoke or args.check:
            ref = TriangularSolver.plan(L, k=8, backend="scan", cache=cache)
            bitwise = np.array_equal(x_rows, np.asarray(ref.solve(b)))
            print(f"  bitwise vs scan: {bitwise}")
            csv_rows.append((f"rows.{name}.bitwise", int(bitwise), ""))
            if not bitwise:
                ok = False
        if name.startswith("band") and ratio > 0.25:
            print(f"  FAIL halo_ratio {ratio} > 0.25 on banded instance")
            ok = False

    if not args.smoke:
        # the scale claim: the partition exceeds any single shard's plan
        d = rows.bound.describe()
        per_shard = d["n_loc"] + d["n_halo"]
        print(f"N={n}: per-shard slots {per_shard} "
              f"({per_shard / n:.2%} of the full plan)")
        csv_rows.append(("rows.big.per_shard_frac",
                         round(per_shard / n, 4), ""))
        if per_shard >= n:
            ok = False

    if args.json:
        write_json_rows(args.json, csv_rows, ["shard_solve"],
                        smoke=args.smoke, devices=n_dev)
    if not ok:
        print("SMOKE FAILED", file=sys.stderr)
        return 1
    print("ok")
    return 0


def main(argv=None) -> int:
    p = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    p.add_argument("--smoke", action="store_true",
                   help="small matrices, assert bitwise + halo_ratio bound")
    p.add_argument("--check", action="store_true",
                   help="bitwise-check vs scan even on the full run")
    p.add_argument("--json", metavar="PATH", default=None)
    p.add_argument("--n", type=int, default=1_000_000,
                   help="rows of the large narrow-band instance (full run)")
    p.add_argument("--model-cap", type=int, default=250_000,
                   help="skip the all_gather baseline above this n "
                        "(its O(k*T) traffic makes big runs pointless)")
    return run(p.parse_args(argv))


if __name__ == "__main__":
    sys.exit(main())
