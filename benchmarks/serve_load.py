"""Serving throughput — pattern-routed microbatching vs one-at-a-time.

Replays request mixes over the 9-matrix autotune corpus through
``repro.serve.SolveService`` and reports, per mix:

  * **batched**  — the real service (``max_batch`` > 1, microbatching);
  * **baseline** — the same service machinery with ``max_batch=1``
    (every request is its own solve: the one-request-at-a-time floor);
  * **speedup**  — batched/baseline solves-per-second, with p50/p99
    latency for both.

Mixes (``repro.serve.loadgen``): ``hot`` (geometric skew — the regime
the paper's §7.7 amortization argument targets, acceptance bar: >= 2x),
``uniform``, and ``adversarial`` (many distinct cold patterns — nothing
coalesces; reported so the cost of the worst case is visible, not
asserted).

Warm-up compiles every (plan, batch-width) XLA variant and then resets
the telemetry, so measured percentiles reflect steady-state serving.
Output: human table + ``repro-bench-rows/v1`` JSON (``--json``), the
same schema as ``benchmarks.run --json``.

  PYTHONPATH=src:. python -m benchmarks.serve_load --json serve.json
  PYTHONPATH=src:. python -m benchmarks.serve_load --smoke   # CI: validate
"""
from __future__ import annotations

import argparse
import sys

import numpy as np

from benchmarks.common import geomean, write_json_rows
from repro.pipeline import PlanCache
from repro.serve import (
    SolveService,
    pad_width,
    patterns_for_mix,
    pretty,
    run_closed_loop,
)

# closed-loop concurrency bounds the largest possible batch: with
# n_clients in flight, the hot route (mix weight ~0.5) sees ~n_clients/2
# concurrent requests, so n_clients = 2*max_batch lets hot batches fill
DEFAULTS = dict(
    max_batch=16,
    max_wait_us=2000,
    n_clients=32,
    requests_per_client=25,
    strategy="auto",
    backend="scan",
)


def _warm(service: SolveService, patterns) -> None:
    """Compile every (plan, pow2 batch width) XLA variant up front, then
    zero the telemetry so measurements see steady state."""
    widths = sorted(
        {pad_width(m, service.max_batch) for m in range(1, service.max_batch + 1)}
    )
    for fp, n in patterns:
        solver = service.pattern(fp).solver_for(service.pattern(fp).current)
        for w in widths:
            np.asarray(solver.solve(np.zeros((n, w), np.float32)))
    service.metrics.reset()


def _measure(
    mix: str,
    *,
    cache: PlanCache,
    max_batch: int,
    max_wait_us: int,
    n_clients: int,
    requests_per_client: int,
    strategy: str,
    backend: str,
    validate: bool,
    n_adversarial: int = 12,
) -> dict:
    with SolveService(
        max_batch=max_batch,
        max_wait_us=max_wait_us,
        cache=cache,
        strategy=strategy,
        backend=backend,
    ) as svc:
        patterns, sampler = patterns_for_mix(
            svc, mix, n_adversarial=n_adversarial, seed=3
        )
        _warm(svc, patterns)
        report = run_closed_loop(
            svc,
            sampler,
            n_clients=n_clients,
            requests_per_client=requests_per_client,
            validate=validate,
        )
    return report


def run(csv_rows, *, smoke: bool = False, opts: dict = None) -> dict:
    o = {**DEFAULTS, **(opts or {})}
    if smoke:
        o.update(n_clients=16, requests_per_client=8)
    validate = smoke or o.pop("validate", False)
    cache = PlanCache()  # shared: baseline re-uses the batched run's plans
    out = {}
    print(
        f"# serve_load — corpus serving, {o['n_clients']} clients x "
        f"{o['requests_per_client']} reqs, max_batch={o['max_batch']}, "
        f"max_wait={o['max_wait_us']}us, strategy={o['strategy']}, "
        f"backend={o['backend']}"
    )
    print(
        f"{'mix':12s} {'mode':9s} {'solves/s':>9s} {'p50 us':>9s} "
        f"{'p99 us':>10s} {'mean batch':>11s} {'mismatch':>9s}"
    )
    speedups = []
    for mix in ("hot", "uniform", "adversarial"):
        per_mode = {}
        for mode, mb in (("batched", o["max_batch"]), ("baseline", 1)):
            rep = _measure(
                mix,
                cache=cache,
                max_batch=mb,
                max_wait_us=o["max_wait_us"],
                n_clients=o["n_clients"],
                requests_per_client=o["requests_per_client"],
                strategy=o["strategy"],
                backend=o["backend"],
                validate=validate,
            )
            per_mode[mode] = rep
            print(
                f"{mix:12s} {mode:9s} {rep['solves_per_sec']:9.1f} "
                f"{rep['latency_us']['p50']:9.1f} "
                f"{rep['latency_us']['p99']:10.1f} "
                f"{rep['mean_batch_size']:11.2f} "
                f"{str(rep['bitwise_mismatches']):>9s}"
            )
            if validate and (
                rep["bitwise_mismatches"] or rep["errors"]
            ):
                raise SystemExit(
                    f"serve_load validation FAILED on mix={mix} mode={mode}: "
                    f"{rep['bitwise_mismatches']} bitwise mismatches, "
                    f"{rep['errors']} errors"
                )
        speed = (
            per_mode["batched"]["solves_per_sec"]
            / max(per_mode["baseline"]["solves_per_sec"], 1e-9)
        )
        speedups.append((mix, speed))
        out[mix] = {**per_mode, "speedup": round(speed, 2)}
        print(f"{mix:12s} {'speedup':9s} {speed:9.2f}x")
        csv_rows.append(
            (
                f"serve.{mix}.batched",
                round(1e6 / max(per_mode["batched"]["solves_per_sec"], 1e-9), 1),
                round(speed, 3),
            )
        )
        csv_rows.append(
            (
                f"serve.{mix}.baseline",
                round(1e6 / max(per_mode["baseline"]["solves_per_sec"], 1e-9), 1),
                1.0,
            )
        )
    print(
        "speedups: "
        + ", ".join(f"{m}={s:.2f}x" for m, s in speedups)
        + f", geomean={geomean([s for _, s in speedups]):.2f}x"
    )
    hot = dict(speedups)["hot"]
    print(
        f"hot-mix acceptance (>=2x batched vs one-at-a-time): "
        f"{'PASS' if hot >= 2.0 else 'MISS'} ({hot:.2f}x)"
    )
    return out


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--json", metavar="PATH", default=None)
    ap.add_argument(
        "--smoke", action="store_true",
        help="short CI run: fewer requests, bitwise-validate every result "
        "against the direct solver, print the metrics dict",
    )
    ap.add_argument("--validate", action="store_true")
    ap.add_argument("--max-batch", type=int, default=DEFAULTS["max_batch"])
    ap.add_argument(
        "--max-wait-us", type=int, default=DEFAULTS["max_wait_us"]
    )
    ap.add_argument("--clients", type=int, default=DEFAULTS["n_clients"])
    ap.add_argument(
        "--requests", type=int, default=DEFAULTS["requests_per_client"],
        help="requests per client",
    )
    ap.add_argument("--strategy", default=DEFAULTS["strategy"])
    ap.add_argument("--backend", default=DEFAULTS["backend"])
    args = ap.parse_args(argv)
    csv_rows = []
    out = run(
        csv_rows,
        smoke=args.smoke,
        opts=dict(
            max_batch=args.max_batch,
            max_wait_us=args.max_wait_us,
            n_clients=args.clients,
            requests_per_client=args.requests,
            strategy=args.strategy,
            backend=args.backend,
            validate=args.validate,
        ),
    )
    if args.smoke:
        # the ISSUE's CI contract: results matched direct solves (enforced
        # above) and the metrics dict is printed
        print(pretty(out["hot"]["batched"]["metrics"]))
    print("\n# CSV: name,us_per_call,derived")
    for name, val, derived in csv_rows:
        print(f"{name},{val},{derived}")
    if args.json:
        write_json_rows(args.json, csv_rows, ["serve"], serve=out)


if __name__ == "__main__":
    main(sys.argv[1:])
