"""Serving throughput — pattern-routed microbatching vs one-at-a-time.

Replays request mixes through ``repro.serve.SolveService`` and reports,
per mix:

  * **batched**  — the real service (``max_batch`` > 1, microbatching;
    the ``width`` mix additionally enables width-class cross-pattern
    batching);
  * **baseline** — the same service machinery with ``max_batch=1``
    (every request is its own solve: the one-request-at-a-time floor);
  * **speedup**  — batched/baseline solves-per-second, with p50/p99
    latency for both.

Mixes (``repro.serve.loadgen``): ``hot`` (geometric skew over the
9-matrix autotune corpus — the regime the paper's §7.7 amortization
argument targets, acceptance bar: >= 2x), ``uniform``, ``adversarial``
(many distinct cold patterns — nothing coalesces; reported so the cost
of the worst case is visible, not asserted), and ``width`` (several
structurally-identical patterns in ONE width class — classic
per-fingerprint routing cannot coalesce them, width-class batching
groups them into single vmapped solves; acceptance bar: >= 1.5x).

``--sweep-workers 1,2`` additionally scales the batched configuration
over worker counts per mix (the n_workers x mix study): acceptance is
that multi-worker throughput never drops below 0.7x the single-worker
run (workers own distinct routes; more workers must not serialize).
The sweep also runs a backend axis: the hot mix served through the
row-partitioned distributed backend (``shard="rows"``, reported as
``serve.sweep.hot.rows``) on a forced multi-device CPU mesh.

``--mode continuous`` runs the continuous-batching study instead: the
hot and width mixes replayed OPEN-loop (fixed offered load) against
``mode="microbatch"`` and ``mode="continuous"`` services, bitwise
validation on every completion. The microbatch path pays its
batch-formation deadline (``max_wait_us``) plus the drain barrier
between dispatches on every request's tail; the slot engine pays
neither — acceptance is continuous open-loop client p99 >= 1.3x better
at the same offered load on both mixes.

Warm-up compiles every (plan, batch-width) XLA variant and then resets
the telemetry, so measured percentiles reflect steady-state serving.
Output: human table + ``repro-bench-rows/v1`` JSON (``--json``), the
same schema as ``benchmarks.run --json``.

  PYTHONPATH=src:. python -m benchmarks.serve_load --json serve.json
  PYTHONPATH=src:. python -m benchmarks.serve_load --smoke --workers 2
"""
from __future__ import annotations

import argparse
import sys

import numpy as np

from benchmarks.common import geomean, write_json_rows
from repro.pipeline import PlanCache
from repro.serve import (
    SolveService,
    patterns_for_mix,
    pretty,
    run_closed_loop,
    run_open_loop,
)

# closed-loop concurrency bounds the largest possible batch: with
# n_clients in flight, the hot route (mix weight ~0.5) sees ~n_clients/2
# concurrent requests, so n_clients = 2*max_batch lets hot batches fill
DEFAULTS = dict(
    max_batch=16,
    max_wait_us=2000,
    n_clients=32,
    requests_per_client=25,
    n_workers=1,
    strategy="auto",
    backend="scan",
)

# acceptance bars: batched vs one-at-a-time throughput per asserted mix
ACCEPT = {"hot": 2.0, "width": 1.5}

# continuous study: open-loop pacing + the p99 acceptance bar
# 150Hz sits where the microbatch formation deadline dominates its tail
# while neither mode saturates the host — the regime the continuous
# engine targets; best-of-3 damps shared-host scheduler noise
CONT_DEFAULTS = dict(rate_hz=150.0, n_requests=400, n_slots=None, trials=3)
CONT_ACCEPT = 1.3  # continuous vs microbatch open-loop client p99


def _warm(service: SolveService, patterns) -> None:
    """Compile every (plan, batch width) XLA variant serving can
    dispatch — including the banked grouped variants when width-class
    batching is on — then zero the telemetry so measurements see steady
    state."""
    del patterns  # the service knows its own registrations
    service.prewarm()
    service.metrics.reset()


def _measure(
    mix: str,
    *,
    cache: PlanCache,
    max_batch: int,
    max_wait_us: int,
    n_clients: int,
    requests_per_client: int,
    n_workers: int,
    width_class: bool,
    strategy: str,
    backend: str,
    validate: bool,
    n_adversarial: int = 12,
    plan_extra: dict = None,
) -> dict:
    with SolveService(
        max_batch=max_batch,
        max_wait_us=max_wait_us,
        n_workers=n_workers,
        width_class_batching=width_class,
        cache=cache,
        strategy=strategy,
        backend=backend,
        **(plan_extra or {}),
    ) as svc:
        patterns, sampler = patterns_for_mix(
            svc, mix, n_adversarial=n_adversarial, seed=3
        )
        _warm(svc, patterns)
        report = run_closed_loop(
            svc,
            sampler,
            n_clients=n_clients,
            requests_per_client=requests_per_client,
            validate=validate,
        )
    return report


def _measure_open(
    mix: str,
    *,
    cache: PlanCache,
    service_kwargs: dict,
    rate_hz: float,
    n_requests: int,
) -> dict:
    """One open-loop run of ``mix`` against a fresh service — bitwise
    validation always on (the continuous study's acceptance criterion
    asserts the served-equals-direct contract on every completion).

    The warmed process holds a large long-lived object graph (plans,
    bound solvers, jit caches); left in the young generations it makes
    every GC pass during the measurement a multi-ms pause that lands
    straight in the dispatch thread's tail. ``gc.freeze`` after warm-up
    — the standard serving-process move — takes it out of the scan set
    for BOTH modes; ``gc.unfreeze`` restores normal collection between
    trials so the harness itself never leaks."""
    import gc

    with SolveService(cache=cache, **service_kwargs) as svc:
        patterns, sampler = patterns_for_mix(svc, mix, seed=3)
        _warm(svc, patterns)
        gc.collect()
        gc.freeze()
        try:
            report = run_open_loop(
                svc,
                sampler,
                rate_hz=rate_hz,
                n_requests=n_requests,
                validate=True,
            )
        finally:
            gc.unfreeze()
    return report


def run_continuous(csv_rows, *, smoke: bool = False, opts: dict = None) -> dict:
    """The continuous-batching study: microbatch vs continuous at the
    same offered (open-loop) load on the hot and width mixes.

    Each mode's open-loop measurement is the best (min client p99) of
    ``trials`` runs: a shared-host scheduler hiccup can only INFLATE a
    run's tail, so min-of-trials estimates the mode's real p99 and both
    modes get identical treatment. The bitwise served-equals-direct
    contract is asserted on every completion of every trial, kept or
    discarded."""
    o = {**DEFAULTS, **CONT_DEFAULTS, **(opts or {})}
    if smoke:
        o.update(n_requests=150, trials=2)
    cache = PlanCache()  # shared: both modes re-use one set of plans
    out = {}
    print(
        f"# serve_load --mode continuous — open loop @ {o['rate_hz']:g}Hz"
        f" x {o['n_requests']} reqs, best-of-{o['trials']} trials, "
        f"max_batch={o['max_batch']}, "
        f"max_wait={o['max_wait_us']}us, "
        f"n_slots={o['n_slots'] or o['max_batch']}, "
        f"strategy={o['strategy']}, backend={o['backend']}"
    )
    print(
        f"{'mix':8s} {'mode':11s} {'solves/s':>9s} {'p50 us':>9s} "
        f"{'p99 us':>10s} {'p99.9 us':>10s} {'mismatch':>9s}"
    )
    ratios = []
    base = dict(
        max_batch=o["max_batch"],
        max_wait_us=o["max_wait_us"],
        n_workers=o["n_workers"],
        strategy=o["strategy"],
        backend=o["backend"],
    )
    for mix in ("hot", "width"):
        per_mode = {}
        for mode, extra in (
            # the width mix is the cross-pattern regime, so the
            # microbatch side gets its best configuration for it
            ("microbatch", dict(width_class_batching=(mix == "width"))),
            ("continuous", dict(mode="continuous", n_slots=o["n_slots"])),
        ):
            rep = None
            for _ in range(o["trials"]):
                trial = _measure_open(
                    mix,
                    cache=cache,
                    service_kwargs={**base, **extra},
                    rate_hz=o["rate_hz"],
                    n_requests=o["n_requests"],
                )
                if trial["bitwise_mismatches"] or trial["errors"]:
                    raise SystemExit(
                        f"continuous study validation FAILED on mix={mix} "
                        f"mode={mode}: {trial['bitwise_mismatches']} "
                        f"bitwise mismatches, {trial['errors']} errors"
                    )
                if (
                    rep is None
                    or trial["client_latency_us"]["p99"]
                    < rep["client_latency_us"]["p99"]
                ):
                    rep = trial
            per_mode[mode] = rep
            lat = rep["client_latency_us"]
            print(
                f"{mix:8s} {mode:11s} {rep['solves_per_sec']:9.1f} "
                f"{lat['p50']:9.1f} {lat['p99']:10.1f} "
                f"{lat['p99.9']:10.1f} "
                f"{str(rep['bitwise_mismatches']):>9s}"
            )
        ratio = per_mode["microbatch"]["client_latency_us"]["p99"] / max(
            per_mode["continuous"]["client_latency_us"]["p99"], 1e-9
        )
        ratios.append((mix, ratio))
        out[mix] = {**per_mode, "p99_ratio": round(ratio, 2)}
        print(f"{mix:8s} {'p99 ratio':11s} {ratio:9.2f}x")
        for mode in ("microbatch", "continuous"):
            csv_rows.append(
                (
                    f"serve.continuous.{mix}.{mode}",
                    per_mode[mode]["client_latency_us"]["p99"],
                    round(ratio, 3) if mode == "continuous" else 1.0,
                )
            )
    ok = True
    for mix, ratio in ratios:
        passed = ratio >= CONT_ACCEPT
        ok = ok and passed
        print(
            f"{mix}-mix acceptance (continuous p99 >= {CONT_ACCEPT:g}x "
            f"better open-loop): {'PASS' if passed else 'MISS'} "
            f"({ratio:.2f}x)"
        )
    out["accepted"] = ok
    return out


def run(csv_rows, *, smoke: bool = False, opts: dict = None) -> dict:
    o = {**DEFAULTS, **(opts or {})}
    if smoke:
        o.update(n_clients=16, requests_per_client=8)
    validate = smoke or o.pop("validate", False)
    sweep_workers = o.pop("sweep_workers", None)
    cache = PlanCache()  # shared: baseline re-uses the batched run's plans
    out = {}
    print(
        f"# serve_load — corpus serving, {o['n_clients']} clients x "
        f"{o['requests_per_client']} reqs, max_batch={o['max_batch']}, "
        f"max_wait={o['max_wait_us']}us, workers={o['n_workers']}, "
        f"strategy={o['strategy']}, backend={o['backend']}"
    )
    print(
        f"{'mix':12s} {'mode':9s} {'solves/s':>9s} {'p50 us':>9s} "
        f"{'p99 us':>10s} {'mean batch':>11s} {'mismatch':>9s}"
    )
    speedups = []
    for mix in ("hot", "uniform", "adversarial", "width"):
        per_mode = {}
        for mode, mb in (("batched", o["max_batch"]), ("baseline", 1)):
            rep = _measure(
                mix,
                cache=cache,
                max_batch=mb,
                max_wait_us=o["max_wait_us"],
                n_clients=o["n_clients"],
                requests_per_client=o["requests_per_client"],
                n_workers=o["n_workers"],
                # the width mix is the cross-pattern regime; grouping is
                # meaningless at max_batch=1, so the baseline skips it
                width_class=(mix == "width" and mode == "batched"),
                strategy=o["strategy"],
                backend=o["backend"],
                validate=validate,
            )
            per_mode[mode] = rep
            print(
                f"{mix:12s} {mode:9s} {rep['solves_per_sec']:9.1f} "
                f"{rep['latency_us']['p50']:9.1f} "
                f"{rep['latency_us']['p99']:10.1f} "
                f"{rep['mean_batch_size']:11.2f} "
                f"{str(rep['bitwise_mismatches']):>9s}"
            )
            if validate and (
                rep["bitwise_mismatches"] or rep["errors"]
            ):
                raise SystemExit(
                    f"serve_load validation FAILED on mix={mix} mode={mode}: "
                    f"{rep['bitwise_mismatches']} bitwise mismatches, "
                    f"{rep['errors']} errors"
                )
        speed = (
            per_mode["batched"]["solves_per_sec"]
            / max(per_mode["baseline"]["solves_per_sec"], 1e-9)
        )
        speedups.append((mix, speed))
        out[mix] = {**per_mode, "speedup": round(speed, 2)}
        print(f"{mix:12s} {'speedup':9s} {speed:9.2f}x")
        csv_rows.append(
            (
                f"serve.{mix}.batched",
                round(1e6 / max(per_mode["batched"]["solves_per_sec"], 1e-9), 1),
                round(speed, 3),
            )
        )
        csv_rows.append(
            (
                f"serve.{mix}.baseline",
                round(1e6 / max(per_mode["baseline"]["solves_per_sec"], 1e-9), 1),
                1.0,
            )
        )
    print(
        "speedups: "
        + ", ".join(f"{m}={s:.2f}x" for m, s in speedups)
        + f", geomean={geomean([s for _, s in speedups]):.2f}x"
    )
    by_mix = dict(speedups)
    for mix, bar in ACCEPT.items():
        s = by_mix[mix]
        print(
            f"{mix}-mix acceptance (>={bar:g}x batched vs one-at-a-time): "
            f"{'PASS' if s >= bar else 'MISS'} ({s:.2f}x)"
        )
    if sweep_workers:
        out["worker_sweep"] = run_worker_sweep(
            csv_rows, sweep_workers, o, cache=cache, validate=validate
        )
    return out


def run_worker_sweep(
    csv_rows, workers_list, o: dict, *, cache: PlanCache, validate: bool
) -> dict:
    """The n_workers x mix scaling study: batched configuration only,
    throughput per worker count. Distinct routes dispatch to distinct
    workers, so adding workers must never serialize a mix — acceptance:
    every multi-worker run >= 0.7x its single-worker throughput (GIL-
    bound small solves cannot promise speedups; regressions they CAN
    promise to avoid)."""
    sweep = {}
    print(f"\n# worker sweep — n_workers in {workers_list}")
    print(f"{'mix':12s} " + " ".join(f"{f'w={w}':>10s}" for w in workers_list))
    ok = True
    for mix in ("hot", "uniform", "adversarial", "width"):
        row = {}
        for nw in workers_list:
            rep = _measure(
                mix,
                cache=cache,
                max_batch=o["max_batch"],
                max_wait_us=o["max_wait_us"],
                n_clients=o["n_clients"],
                requests_per_client=o["requests_per_client"],
                n_workers=nw,
                width_class=(mix == "width"),
                strategy=o["strategy"],
                backend=o["backend"],
                validate=validate,
            )
            row[nw] = rep["solves_per_sec"]
            csv_rows.append(
                (
                    f"serve.sweep.{mix}.w{nw}",
                    round(1e6 / max(rep["solves_per_sec"], 1e-9), 1),
                    round(rep["solves_per_sec"] / max(row[workers_list[0]], 1e-9), 3),
                )
            )
        print(
            f"{mix:12s} "
            + " ".join(f"{row[w]:10.1f}" for w in workers_list)
        )
        base = row[workers_list[0]]
        for nw in workers_list[1:]:
            if row[nw] < 0.7 * base:
                ok = False
                print(
                    f"  !! {mix}: n_workers={nw} fell to "
                    f"{row[nw] / max(base, 1e-9):.2f}x of "
                    f"n_workers={workers_list[0]}"
                )
        sweep[mix] = row
    print(
        "worker-sweep acceptance (multi-worker >= 0.7x single-worker): "
        f"{'PASS' if ok else 'MISS'}"
    )

    # the sharded backend axis: the hot mix once more through the row-
    # partitioned distributed backend (shard="rows"), so the sweep also
    # covers the serving cost of halo-exchange solves. Needs a multi-
    # device process view — main() forces one via XLA_FLAGS when the
    # sweep is requested, but respects a pre-set environment.
    import jax

    n_dev = len(jax.devices())
    if n_dev < 2:
        print(
            "rows backend axis skipped: single-device process "
            "(set XLA_FLAGS=--xla_force_host_platform_device_count=N)"
        )
        return sweep
    mesh = jax.make_mesh((1, n_dev), ("data", "model"))
    nw = workers_list[0]
    print(f"\n# backend axis — shard='rows' on a {n_dev}-device mesh "
          f"(n_workers={nw})")
    for mix in ("hot",):
        rep = _measure(
            mix,
            cache=PlanCache(),  # distinct binding: never share plans
            max_batch=o["max_batch"],
            max_wait_us=o["max_wait_us"],
            n_clients=o["n_clients"],
            requests_per_client=o["requests_per_client"],
            n_workers=nw,
            width_class=False,
            strategy=o["strategy"],
            backend="distributed",
            validate=validate,
            plan_extra=dict(mesh=mesh, shard="rows"),
        )
        sps = rep["solves_per_sec"]
        base = sweep[mix][nw]
        print(f"{mix + '@rows':12s} {sps:10.1f}  "
              f"({sps / max(base, 1e-9):.2f}x of scan)")
        sweep[f"{mix}@rows"] = {nw: sps}
        csv_rows.append(
            (
                f"serve.sweep.{mix}.rows",
                round(1e6 / max(sps, 1e-9), 1),
                round(sps / max(base, 1e-9), 3),
            )
        )
    return sweep


def _export_trace(trace_buf, path, csv_rows) -> None:
    """Finish a ``--trace`` run: stop tracing, write the Chrome trace,
    and fold the per-span aggregate into the CSV/JSON rows."""
    if trace_buf is None:
        return
    from repro import obs

    obs.disable()
    obs.export_chrome_trace(path, trace_buf)
    csv_rows.extend(obs.metrics_rows(trace_buf))
    print(f"\n[trace: {len(trace_buf)} spans -> {path}]")


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--json", metavar="PATH", default=None)
    ap.add_argument(
        "--smoke", action="store_true",
        help="short CI run: fewer requests, bitwise-validate every result "
        "against the direct solver, print the metrics dict",
    )
    ap.add_argument("--validate", action="store_true")
    ap.add_argument("--max-batch", type=int, default=DEFAULTS["max_batch"])
    ap.add_argument(
        "--max-wait-us", type=int, default=DEFAULTS["max_wait_us"]
    )
    ap.add_argument("--clients", type=int, default=DEFAULTS["n_clients"])
    ap.add_argument(
        "--requests", type=int, default=DEFAULTS["requests_per_client"],
        help="requests per client",
    )
    ap.add_argument(
        "--workers", type=int, default=DEFAULTS["n_workers"],
        help="service worker threads",
    )
    ap.add_argument(
        "--sweep-workers", metavar="N,N,...", default=None,
        help="additionally run the batched config at each worker count "
        "(the n_workers x mix scaling study)",
    )
    ap.add_argument("--strategy", default=DEFAULTS["strategy"])
    ap.add_argument("--backend", default=DEFAULTS["backend"])
    ap.add_argument(
        "--mode", choices=("microbatch", "continuous"),
        default="microbatch",
        help="continuous: open-loop p99 study, microbatch vs the "
        "resident-slot engine at the same offered load",
    )
    ap.add_argument(
        "--rate-hz", type=float, default=CONT_DEFAULTS["rate_hz"],
        help="offered load of the continuous study's open loop",
    )
    ap.add_argument(
        "--n-requests", type=int, default=CONT_DEFAULTS["n_requests"],
        help="open-loop request count of the continuous study",
    )
    ap.add_argument(
        "--slots", type=int, default=None,
        help="resident lanes per width class (default: max_batch)",
    )
    ap.add_argument(
        "--trials", type=int, default=CONT_DEFAULTS["trials"],
        help="open-loop runs per mode; each mode reports its best "
        "(min p99) trial",
    )
    ap.add_argument(
        "--trace", metavar="PATH", default=None,
        help="trace the run with repro.obs and write a Chrome "
             "trace_event JSON to PATH (serve.microbatch / "
             "serve.grouped_batch / serve.slot_pass spans plus the "
             "plan/cache/backend layers underneath)",
    )
    args = ap.parse_args(argv)
    if args.sweep_workers:
        # the sweep's shard="rows" backend axis needs a multi-device
        # process view; must land before jax initializes its CPU client
        # (respects an explicitly pre-set environment)
        import os

        os.environ.setdefault(
            "XLA_FLAGS", "--xla_force_host_platform_device_count=8"
        )
    trace_buf = None
    if args.trace:
        from repro import obs

        trace_buf = obs.enable()
    csv_rows = []
    if args.mode == "continuous":
        out = run_continuous(
            csv_rows,
            smoke=args.smoke,
            opts=dict(
                max_batch=args.max_batch,
                max_wait_us=args.max_wait_us,
                n_workers=args.workers,
                strategy=args.strategy,
                backend=args.backend,
                rate_hz=args.rate_hz,
                n_requests=args.n_requests,
                n_slots=args.slots,
                trials=args.trials,
            ),
        )
        if args.smoke:
            print(pretty(out["hot"]["continuous"]["metrics"]))
        _export_trace(trace_buf, args.trace, csv_rows)
        print("\n# CSV: name,us_per_call,derived")
        for name, val, derived in csv_rows:
            print(f"{name},{val},{derived}")
        if args.json:
            write_json_rows(
                args.json, csv_rows, ["serve"], serve=out
            )
        return
    out = run(
        csv_rows,
        smoke=args.smoke,
        opts=dict(
            max_batch=args.max_batch,
            max_wait_us=args.max_wait_us,
            n_clients=args.clients,
            requests_per_client=args.requests,
            n_workers=args.workers,
            sweep_workers=[int(x) for x in args.sweep_workers.split(",")]
            if args.sweep_workers
            else None,
            strategy=args.strategy,
            backend=args.backend,
            validate=args.validate,
        ),
    )
    if args.smoke:
        # the ISSUE's CI contract: results matched direct solves (enforced
        # above) and the metrics dict is printed
        print(pretty(out["hot"]["batched"]["metrics"]))
    _export_trace(trace_buf, args.trace, csv_rows)
    print("\n# CSV: name,us_per_call,derived")
    for name, val, derived in csv_rows:
        print(f"{name},{val},{derived}")
    if args.json:
        write_json_rows(args.json, csv_rows, ["serve"], serve=out)


if __name__ == "__main__":
    main(sys.argv[1:])
