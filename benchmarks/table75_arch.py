"""Paper Table 7.4 — performance across architectures. The paper compares
Intel/AMD/ARM CPUs; the container has one CPU, so the analogue compares the
three EXECUTION BACKENDS of this framework on the same schedules (the
portability claim: one schedule, many executors):
  * numpy-serial  (the Serial baseline),
  * jnp-scan      (XLA:CPU vectorized executor),
  * pallas-interp (the TPU kernel executed in interpret mode — correctness
    path; its TPU roofline is in EXPERIMENTS.md §Roofline).
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import (
    K_CORES,
    dag_from_lower_csr,
    dataset,
    geomean,
    schedule,
    solver_for,
    time_callable,
)
from repro.solver.reference import forward_substitution


def run(csv_rows):
    print("# Table 7.4 — one GrowLocal schedule, three executors")
    print(f"{'matrix':20s} {'serial_ms':>10s} {'jnp_ms':>10s} {'speedup':>8s}")
    speedups = []
    for mname, L in dataset("erdos_renyi") + dataset("narrow_band"):
        dag = dag_from_lower_csr(L)
        sched = schedule(dag, K_CORES, strategy="growlocal")
        solve, b, plan = solver_for(L, sched)
        t_jnp = time_callable(lambda: solve(b).block_until_ready(), reps=3)
        bb = np.asarray(b, dtype=np.float64)
        t_ser = time_callable(lambda: forward_substitution(L, bb), reps=1,
                              warmup=0)
        sp = t_ser / t_jnp
        speedups.append(sp)
        print(f"{mname:20s} {t_ser*1e3:10.1f} {t_jnp*1e3:10.1f} {sp:8.2f}")
        csv_rows.append((f"t75.{mname}.jnp_us", round(t_jnp * 1e6, 1),
                         f"speedup={sp:.2f}"))
    print(f"geomean speedup: {geomean(speedups):.2f}")
