"""Paper §7.3 — impact of Funnel coarsening: scheduling time, supersteps,
BSP cost, and coarse-graph size, GrowLocal vs Funnel+GrowLocal."""
from __future__ import annotations

import time

from benchmarks.common import (
    ALL_DATASETS,
    K_CORES,
    bsp_cost,
    dag_from_lower_csr,
    dataset,
    geomean,
    schedule,
)
from repro.core import coarsen_dag, funnel_partition, transitive_sparsify


def run(csv_rows):
    print("# §7.3 — Funnel coarsening ablation")
    print(f"{'dataset':14s} {'sched_speedup':>13s} {'coarse_ratio':>12s} "
          f"{'ss_GL':>8s} {'ss_F+GL':>8s} {'cost_ratio':>10s}")
    for ds in ALL_DATASETS:
        sp, cr, s1, s2, costr = [], [], [], [], []
        for mname, L in dataset(ds):
            dag = dag_from_lower_csr(L)
            t0 = time.perf_counter()
            gl = schedule(dag, K_CORES, strategy="growlocal")
            t_gl = time.perf_counter() - t0
            t0 = time.perf_counter()
            fgl = schedule(dag, K_CORES, strategy="funnel-gl")
            t_fgl = time.perf_counter() - t0
            part = funnel_partition(transitive_sparsify(dag), max_size=64)
            c = coarsen_dag(transitive_sparsify(dag), part)
            sp.append(t_gl / t_fgl)
            cr.append(dag.n / c.coarse.n)
            s1.append(gl.n_supersteps)
            s2.append(fgl.n_supersteps)
            costr.append(bsp_cost(dag, gl) / bsp_cost(dag, fgl))
        row = (geomean(sp), geomean(cr), geomean(s1), geomean(s2), geomean(costr))
        print(f"{ds:14s} {row[0]:13.2f} {row[1]:12.2f} {row[2]:8.1f} "
              f"{row[3]:8.1f} {row[4]:10.3f}")
        csv_rows.append((f"t73.{ds}.sched_speedup", round(row[0], 3),
                         f"coarse_ratio={row[1]:.2f}"))
