"""Auto-strategy shootout — ``strategy="auto"`` vs best/worst fixed.

For every matrix in the autotuner's scenario corpus (``repro.autotune``):

  * model view — BSP cost (§2.2) of the auto-selected config vs the best
    and worst of the 7 fixed registry strategies at default options. The
    acceptance bar (asserted in tests/test_autotune.py, reported here):
    auto <= 1.1 * best and auto < worst on every corpus matrix;
  * measured view — wall-clock of an actual solve with the auto plan vs
    the best-fixed and worst-fixed plans (scan executor, k=8).

Also prints which strategy auto picked and the regime label it derived,
so a selector regression is visible at a glance.
"""
from __future__ import annotations

from benchmarks.common import (
    K_CORES,
    bsp_cost,
    dag_from_lower_csr,
    dataset,
    geomean,
    solver_for,
    time_callable,
)
from repro.autotune import corpus_entry
from repro.pipeline import PlanCache, TriangularSolver, available_strategies, schedule


def run(csv_rows):
    print("# Table 7.x — strategy='auto' vs fixed strategies (corpus)")
    print(
        f"{'matrix':16s} {'regime':7s} {'auto->':10s} "
        f"{'cost a/b/w':>20s} {'vs best':>8s} {'vs worst':>9s} "
        f"{'wall a/b/w (us)':>22s}"
    )
    ratios_best, ratios_worst, wall_ratios = [], [], []
    cache = PlanCache()
    for mname, L in dataset("corpus"):
        entry = corpus_entry(mname)
        dag = dag_from_lower_csr(L)
        costs = {
            s: bsp_cost(dag, schedule(dag, K_CORES, strategy=s))
            for s in available_strategies()
        }
        best = min(costs, key=costs.get)
        worst = max(costs, key=costs.get)

        auto = TriangularSolver.plan(L, strategy="auto", k=K_CORES, cache=cache)
        sel = auto.selection
        a_cost = sel.cost

        def timed(strategy):
            solve, b, _ = solver_for(L, strategy=strategy, cache=cache)
            return time_callable(lambda: solve(b).block_until_ready())

        t_auto = timed("auto")
        t_best = timed(best)
        t_worst = timed(worst)

        rb, rw = a_cost / costs[best], a_cost / costs[worst]
        ratios_best.append(rb)
        ratios_worst.append(rw)
        wall_ratios.append(t_auto / t_best)
        print(
            f"{mname:16s} {sel.regime:7s} {sel.strategy:10s} "
            f"{a_cost:8.0f}/{costs[best]:5.0f}/{costs[worst]:6.0f} "
            f"{rb:7.2f}x {rw:8.2f}x "
            f"{t_auto*1e6:7.0f}/{t_best*1e6:6.0f}/{t_worst*1e6:7.0f}"
        )
        csv_rows.append((f"t7x.{mname}.auto", round(t_auto * 1e6, 1), round(rb, 3)))
        csv_rows.append((f"t7x.{mname}.best_{best}", round(t_best * 1e6, 1), 1.0))
        csv_rows.append(
            (f"t7x.{mname}.worst_{worst}", round(t_worst * 1e6, 1), round(1 / rw, 3))
        )
    print(
        f"geomean: auto/best cost {geomean(ratios_best):.3f}x, "
        f"auto/worst cost {geomean(ratios_worst):.3f}x, "
        f"auto/best wall {geomean(wall_ratios):.2f}x"
    )
    print(
        f"selector overhead amortized: {cache.stats.selections} selections, "
        f"{cache.stats.selection_hits} selection hits"
    )
