"""Elastic-mode benchmark — barrier/step accounting + wall-clock,
elastic vs bulk-synchronous (ISSUE 6).

For each corpus family the driver plans the same schedule twice — once
bulk-synchronous, once ``mode="elastic"`` — and reports, per matrix:

  * the **certificate** numbers from ``ExecPlan.stats()["elastic"]``:
    scan trip count T vs fused macro-steps ceil(T/slack)
    (``step_fusion``), and superstep barriers vs readiness-fused
    barriers (``barrier_fusion`` — the distributed-barrier certificate);
  * the **model** numbers from the step-granular cost the autotuner's
    elastic rule uses (``step_cost`` / ``elastic_cost``, §2.2 with
    ``l_step`` per scan step instead of ``L`` per barrier);
  * the **measured** median solve wall-clock of both bindings, with the
    results checked bitwise-equal (an elastic solve that drifts is a
    scheduling bug, not a rounding artifact — same op order by design).

Deep-DAG regimes (chain, narrow band — where T dominates and the paper's
barrier-count argument says BSP loses) are foregrounded at N=20k; the
shallow/wide families ride along to show elastic is *safe* but not
expected to win there.

Output: human table + ``repro-bench-rows/v1`` JSON (``--json``), the
same schema as ``benchmarks.run --json`` / ``benchmarks.inspector_bench``.

  PYTHONPATH=src:. python -m benchmarks.table7e_elastic --json el.json
  PYTHONPATH=src:. python -m benchmarks.table7e_elastic --smoke  # CI:
      corpus-size matrices; asserts bitwise equality + >=2x step fusion
      on the deep-DAG rows
"""
from __future__ import annotations

import argparse
import sys

import numpy as np

from benchmarks.common import time_callable, write_json_rows
from repro.core import (
    DEFAULT_SLACK,
    elastic_cost,
    step_cost,
)
from repro.pipeline import TriangularSolver, schedule
from repro.sparse import (
    dag_from_lower_csr,
    erdos_renyi_lower,
    ichol0,
    narrow_band_lower,
    poisson2d_matrix,
)
from repro.sparse.csr import lower_triangle_of

K = 8
STRATEGY = "growlocal"
# rows whose regime the autotuner's elastic rule targets — the smoke
# acceptance (>= 2x step fusion) is asserted on exactly these
DEEP = ("chain", "band_narrow", "band_wide")


def _chain_lower(n: int, seed: int = 0) -> "object":
    from repro.autotune.corpus import chain_lower

    return chain_lower(n, seed=seed)


def matrices(smoke: bool):
    """(name, lower CSR, deep?) triples; deep-DAG families first."""
    if smoke:
        return [
            ("chain", _chain_lower(2_000, seed=105), True),
            ("band_narrow", narrow_band_lower(2_000, 0.14, 10, seed=103),
             True),
            ("band_wide", narrow_band_lower(2_000, 0.03, 42, seed=104),
             True),
            ("poisson2d_ichol", ichol0(poisson2d_matrix(26)), False),
            ("er_dense", erdos_renyi_lower(500, 0.03, seed=102), False),
        ]
    return [
        ("chain", _chain_lower(20_000, seed=105), True),
        ("band_narrow", narrow_band_lower(20_000, 0.14, 10, seed=103), True),
        ("band_wide", narrow_band_lower(20_000, 0.03, 42, seed=104), True),
        ("poisson2d_ichol", ichol0(poisson2d_matrix(110)), False),
        ("poisson2d_110", lower_triangle_of(poisson2d_matrix(110)), False),
        ("er_dense", erdos_renyi_lower(12_000, 0.03 * 500 / 12_000, seed=102),
         False),
    ]


def _bench_matrix(name: str, L, *, reps: int) -> dict:
    bulk = TriangularSolver.plan(L, strategy=STRATEGY, k=K)
    el = TriangularSolver.plan(L, strategy=STRATEGY, k=K, mode="elastic")
    st = el.exec_plan.stats()["elastic"]

    # the autotuner's step-granular model terms, on the same schedule
    dag = dag_from_lower_csr(L)
    s = schedule(dag, K, strategy=STRATEGY)
    c_step = step_cost(dag, s)
    c_elastic = elastic_cost(dag, s, DEFAULT_SLACK)

    rng = np.random.default_rng(0)
    b = rng.standard_normal(L.n_rows).astype(np.float32)
    xb = np.asarray(bulk.solve(b))
    xe = np.asarray(el.solve(b))
    bitwise = bool(np.array_equal(xb, xe))

    t_bulk = time_callable(lambda: np.asarray(bulk.solve(b)), reps=reps)
    t_el = time_callable(lambda: np.asarray(el.solve(b)), reps=reps)

    return {
        "name": name,
        "n": L.n_rows,
        "nnz": L.nnz,
        "slack": st["slack"],
        "n_steps": st["n_steps"],
        "n_macro_steps": st["n_macro_steps"],
        "step_fusion": st["step_fusion"],
        "n_supersteps": st["n_supersteps"],
        "n_fused_supersteps": st["n_fused_supersteps"],
        "barrier_fusion": st["barrier_fusion"],
        "step_cost": c_step,
        "elastic_cost": c_elastic,
        "bitwise_equal": bitwise,
        "bulk_seconds": t_bulk,
        "elastic_seconds": t_el,
        "speedup": t_bulk / t_el,
    }


def run(csv_rows, *, smoke: bool = False) -> dict:
    reps = 3 if smoke else 7
    print(
        f"# table7e_elastic — mode='elastic' (slack={DEFAULT_SLACK}) vs "
        f"bulk-synchronous, {STRATEGY} k={K} on the scan backend"
        f"{' (smoke sizes)' if smoke else ''}"
    )
    print(
        f"{'matrix':18s} {'n':>7s} {'T':>7s} {'macro':>6s} {'fuse':>6s} "
        f"{'barr':>5s} {'bfuse':>6s} {'bulk ms':>9s} {'elast ms':>9s} "
        f"{'speedup':>8s} {'equal':>6s}"
    )
    out = {}
    deep_speedups = []
    for name, L, deep in matrices(smoke):
        r = _bench_matrix(name, L, reps=reps)
        out[name] = r
        print(
            f"{name:18s} {r['n']:7d} {r['n_steps']:7d} "
            f"{r['n_macro_steps']:6d} {r['step_fusion']:5.1f}x "
            f"{r['n_supersteps']:5d} {r['barrier_fusion']:5.1f}x "
            f"{r['bulk_seconds']*1e3:9.2f} {r['elastic_seconds']*1e3:9.2f} "
            f"{r['speedup']:7.2f}x {str(r['bitwise_equal']):>6s}"
        )
        csv_rows.append(
            (f"elastic.{name}.bulk", round(r["bulk_seconds"] * 1e6, 1), 1.0)
        )
        csv_rows.append(
            (f"elastic.{name}.elastic",
             round(r["elastic_seconds"] * 1e6, 1), round(r["speedup"], 3))
        )
        csv_rows.append(
            (f"elastic.{name}.step_fusion", r["n_macro_steps"],
             round(r["step_fusion"], 2))
        )
        if not r["bitwise_equal"]:
            raise SystemExit(
                f"table7e_elastic FAILED: elastic solve on {name!r} is not "
                f"bitwise-equal to the bulk-synchronous solve"
            )
        if deep:
            deep_speedups.append(r["speedup"])
            if r["step_fusion"] < 2.0:
                raise SystemExit(
                    f"table7e_elastic FAILED: deep-DAG row {name!r} fused "
                    f"only {r['step_fusion']:.2f}x (acceptance: >= 2x)"
                )
    print("bitwise equivalence (elastic vs bulk): PASS")
    print(
        f"deep-DAG acceptance (>= 2x step fusion on {', '.join(DEEP)}): PASS"
    )
    if not smoke:
        from benchmarks.common import geomean

        g = geomean(deep_speedups)
        print(f"deep-DAG wall-clock speedup geomean: {g:.2f}x")
        out["deep_geomean_speedup"] = g
    return out


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--json", metavar="PATH", default=None)
    ap.add_argument(
        "--smoke", action="store_true",
        help="short CI run: corpus-size matrices; still asserts bitwise "
        "equality and >=2x deep-DAG step fusion (exits non-zero on miss)",
    )
    args = ap.parse_args(argv)
    csv_rows = []
    out = run(csv_rows, smoke=args.smoke)
    print("\n# CSV: name,us_per_call,derived")
    for name, val, derived in csv_rows:
        print(f"{name},{val},{derived}")
    if args.json:
        write_json_rows(args.json, csv_rows, ["elastic"], elastic=out)


if __name__ == "__main__":
    main(sys.argv[1:])
